//! FedAvgM (Hsu et al., 2019): FedAvg with server-side momentum over the
//! round pseudo-gradient.

use crate::error::FlError;
use crate::runtime::ModelExecutor;

use super::super::client::FitResult;
use super::super::params::{ParamScratch, ParamVector};
use super::{
    weighted_average, AccOutput, AggAccumulator, FoldPlan, Strategy, StreamingMean, TreeMean,
};

/// Decode a `[n u64 LE][n x f32 LE]` blob; `None` on empty or malformed
/// input (treated as "no state yet").
pub(super) fn decode_f32_vec(blob: &[u8]) -> Option<Vec<f32>> {
    if blob.len() < 8 {
        return None;
    }
    let n = u64::from_le_bytes(blob[..8].try_into().unwrap()) as usize;
    let body = &blob[8..];
    if body.len() != 4 * n {
        return None;
    }
    Some(
        body.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect(),
    )
}

/// Server momentum over round updates: `m <- beta m + (avg - global)`,
/// `global <- global + m`.
#[derive(Debug)]
pub struct FedAvgM {
    pub beta: f32,
    momentum: Option<ParamVector>,
}

impl FedAvgM {
    pub fn new(beta: f32) -> Self {
        assert!((0.0..1.0).contains(&beta));
        FedAvgM { beta, momentum: None }
    }

    /// The momentum step, shared by the streaming and batch paths.
    fn apply(&mut self, global: &ParamVector, avg: &ParamVector) -> ParamVector {
        let delta = avg.sub(global);
        let m = match self.momentum.take() {
            Some(mut m) => {
                m.scale(self.beta);
                m.add_scaled(&delta, 1.0);
                m
            }
            None => delta,
        };
        let mut new_global = global.clone();
        new_global.add_scaled(&m, 1.0);
        self.momentum = Some(m);
        new_global
    }
}

impl Strategy for FedAvgM {
    fn name(&self) -> &'static str {
        "fedavgm"
    }

    /// The mean streams at O(P); momentum applies to it in `reduce`.
    fn accumulator(
        &self,
        num_params: usize,
        _expected_clients: usize,
    ) -> Box<dyn AggAccumulator> {
        Box::new(StreamingMean::new(num_params))
    }

    fn accumulator_recycled(
        &self,
        num_params: usize,
        _expected_clients: usize,
        scratch: &ParamScratch,
    ) -> Box<dyn AggAccumulator> {
        Box::new(StreamingMean::recycled(num_params, scratch.clone()))
    }

    fn accumulator_planned(
        &self,
        num_params: usize,
        expected_clients: usize,
        scratch: &ParamScratch,
        plan: FoldPlan,
    ) -> Box<dyn AggAccumulator> {
        match plan {
            FoldPlan::Serial => self.accumulator_recycled(num_params, expected_clients, scratch),
            FoldPlan::Tree => {
                Box::new(TreeMean::recycled(num_params, expected_clients, scratch.clone()))
            }
        }
    }

    fn reduce(
        &mut self,
        global: &ParamVector,
        output: AccOutput,
        executor: Option<&mut ModelExecutor>,
    ) -> Result<ParamVector, FlError> {
        match output {
            AccOutput::Mean(mean) => Ok(self.apply(global, &mean.params)),
            AccOutput::Buffered(results) => self.aggregate(global, &results, executor),
        }
    }

    /// Momentum vector as `[n u64 LE][n x f32 LE]`; empty before round 1.
    fn state_blob(&self) -> Vec<u8> {
        match &self.momentum {
            None => Vec::new(),
            Some(m) => {
                let s = m.as_slice();
                let mut out = Vec::with_capacity(8 + 4 * s.len());
                out.extend_from_slice(&(s.len() as u64).to_le_bytes());
                for x in s {
                    out.extend_from_slice(&x.to_le_bytes());
                }
                out
            }
        }
    }

    fn restore_state(&mut self, blob: &[u8]) {
        self.momentum = decode_f32_vec(blob).map(ParamVector::from_vec);
    }

    fn aggregate(
        &mut self,
        global: &ParamVector,
        results: &[FitResult],
        executor: Option<&mut ModelExecutor>,
    ) -> Result<ParamVector, FlError> {
        let avg = weighted_average(results, executor)?;
        Ok(self.apply(global, &avg))
    }
}
