//! The Flower-shaped federated learning framework with BouquetFL's
//! hardware-restricted client execution as a first-class feature.
//!
//! The library-first entrypoint is [`Experiment::builder`] (validated
//! builder → [`Experiment`] → [`ExperimentReport`]); multi-run sweeps go
//! through [`Campaign`].  The historical [`launch`] function and raw
//! `ServerApp` composition keep working as compatibility shims
//! (DESIGN.md §10).

pub mod attack;
pub mod bouquet;
pub mod campaign;
pub mod client;
pub mod clientmgr;
pub mod events;
pub mod experiment;
pub mod history;
pub mod launcher;
pub mod params;
pub mod population;
pub mod scenario;
pub mod server;
pub mod strategy;

pub use attack::{Attack, AttackConfig, AttackCtx, AttackKind, AttackModel, ATTACK_PRESETS};
pub use bouquet::BouquetContext;
pub use campaign::{Campaign, CampaignCell, CampaignReport, CellOutcome};
pub use client::{ClientApp, ClientId, FitConfig, FitResult, SimClient, TrainClient};
pub use clientmgr::{ClientManager, RoundLedger, Selection};
pub use events::{
    CommDirection, FailureKind, FlEvent, FlObserver, HistoryObserver, ProgressLogger,
    TraceObserver,
};
pub use experiment::{ExecutionMode, Experiment, ExperimentBuilder, ExperimentReport};
pub use history::{History, RoundRecord};
pub use launcher::{launch, HardwareSource, LaunchOptions, LaunchOutcome, PopulationOptions};
pub use params::{ParamScratch, ParamVector};
pub use population::{
    ClientDescriptor, ClientFactory, Population, SimClientFactory, TrainClientFactory,
    DENSE_POPULATION_MAX,
};
pub use scenario::{Scenario, MODEL_KINDS, SCENARIO_PRESETS};
pub use server::{ServerApp, ServerConfig};
pub use strategy::{
    AccOutput, AggAccumulator, BoundedBuffer, FedAdam, FedAvg, FedAvgM, FedProx, FoldPlan,
    Krum, MeanAggregate, Strategy, StreamingMean, TreeMean, TrimmedMean,
};
