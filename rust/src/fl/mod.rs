//! The Flower-shaped federated learning framework with BouquetFL's
//! hardware-restricted client execution as a first-class feature.

pub mod bouquet;
pub mod client;
pub mod clientmgr;
pub mod history;
pub mod launcher;
pub mod params;
pub mod scenario;
pub mod server;
pub mod strategy;

pub use bouquet::BouquetContext;
pub use client::{ClientApp, ClientId, FitConfig, FitResult, SimClient, TrainClient};
pub use clientmgr::{ClientManager, RoundLedger, Selection};
pub use history::{History, RoundRecord};
pub use launcher::{launch, HardwareSource, LaunchOptions, LaunchOutcome};
pub use params::ParamVector;
pub use scenario::{Scenario, SCENARIO_PRESETS};
pub use server::{ServerApp, ServerConfig};
pub use strategy::{
    AccOutput, AggAccumulator, BoundedBuffer, FedAdam, FedAvg, FedAvgM, FedProx, Krum,
    MeanAggregate, Strategy, StreamingMean, TrimmedMean,
};
