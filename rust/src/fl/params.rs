//! Flat model-parameter vectors (f32[P]) and the linear algebra the
//! aggregation strategies need.  Keeping parameters flat end-to-end (the
//! L2 functions are lowered over flat vectors too) removes all pytree
//! bookkeeping from the hot path.

use std::sync::{Arc, Mutex};

/// A flat parameter (or update/gradient) vector.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamVector(Vec<f32>);

/// Thread-safe recycling stash of parameter-sized buffers.
///
/// Every client fit used to allocate a fresh P-sized update vector, and
/// every round a fresh P-sized fold buffer — at population scale those
/// allocations dominate the SimClient hot path.  A `ParamScratch` closes
/// the loop: fits draw their update buffers from it
/// ([`ParamScratch::clone_vector`]), the streaming accumulator
/// (`fl::strategy::StreamingMean::recycled`) returns folded update
/// buffers to it, and the stash is bounded so a
/// one-off burst cannot pin memory.  Cloning a `ParamScratch` clones the
/// *handle* (the stash is shared): the worker pool and the server-side
/// accumulator hold the same stash, so buffers cycle
/// worker → accumulator → worker with zero steady-state allocation.
///
/// Recycling changes no observable: buffers are fully overwritten before
/// use, so engine output stays bit-identical with or without a warm stash.
#[derive(Debug, Clone, Default)]
pub struct ParamScratch {
    f32s: Arc<Mutex<Vec<Vec<f32>>>>,
    f64s: Arc<Mutex<Vec<Vec<f64>>>>,
}

/// Stash bound per element type: enough for a worker pool's in-flight
/// fits plus the accumulator, small enough that extras are simply freed.
const MAX_STASH: usize = 16;

impl ParamScratch {
    /// Recycled clone of `src`: allocation-free once the stash is warm.
    pub fn clone_vector(&self, src: &ParamVector) -> ParamVector {
        let mut buf = self
            .f32s
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(src.as_slice());
        ParamVector(buf)
    }

    /// Take a cleared f32 buffer (capacity whatever the stash had).
    pub fn take_f32(&self) -> Vec<f32> {
        let mut buf = self
            .f32s
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default();
        buf.clear();
        buf
    }

    /// Return a parameter vector's buffer to the stash (bounded; extras
    /// are freed).
    pub fn recycle(&self, v: ParamVector) {
        let mut stash = self.f32s.lock().unwrap_or_else(|e| e.into_inner());
        if stash.len() < MAX_STASH {
            stash.push(v.0);
        }
    }

    /// Take a zero-filled f64 fold buffer of length `len`.
    pub fn take_f64_zeroed(&self, len: usize) -> Vec<f64> {
        let mut buf = self
            .f64s
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default();
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return an f64 fold buffer to the stash (bounded; extras are freed).
    pub fn recycle_f64(&self, buf: Vec<f64>) {
        let mut stash = self.f64s.lock().unwrap_or_else(|e| e.into_inner());
        if stash.len() < MAX_STASH {
            stash.push(buf);
        }
    }

    /// Buffers currently stashed (f32 + f64) — tests assert recycling.
    pub fn stashed(&self) -> usize {
        self.f32s.lock().unwrap_or_else(|e| e.into_inner()).len()
            + self.f64s.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

impl ParamVector {
    pub fn zeros(n: usize) -> Self {
        ParamVector(vec![0.0; n])
    }

    pub fn from_vec(v: Vec<f32>) -> Self {
        ParamVector(v)
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.0
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.0
    }

    pub fn l2_norm(&self) -> f64 {
        self.0.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// `self += alpha * other` (in place, no allocation).
    pub fn add_scaled(&mut self, other: &ParamVector, alpha: f32) {
        assert_eq!(self.len(), other.len());
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            *a += alpha * b;
        }
    }

    /// `self *= alpha`.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.0.iter_mut() {
            *a *= alpha;
        }
    }

    /// `self - other` (allocates).
    pub fn sub(&self, other: &ParamVector) -> ParamVector {
        assert_eq!(self.len(), other.len());
        ParamVector(self.0.iter().zip(&other.0).map(|(a, b)| a - b).collect())
    }

    /// `sum_k weights[k] * vs[k]` — the Rust-native FedAvg kernel
    /// (semantically identical to the Pallas `aggregate` artifact).
    ///
    /// Cache-blocked *and* unroll-and-jammed: iterate over `out` in
    /// L1-sized chunks, and inside each chunk fold **four clients per
    /// sweep**, so the chunk's loads/stores amortise over four updates
    /// instead of one.  Each element still accumulates its clients in
    /// ascending-k order — the jammed loop performs the same additions in
    /// the same per-element sequence as a one-client-at-a-time sweep, so
    /// the result is bit-identical to the plain blocked kernel (and the
    /// naive K-pass oracle stays the differential reference).
    pub fn weighted_sum(vs: &[ParamVector], weights: &[f32]) -> ParamVector {
        assert_eq!(vs.len(), weights.len());
        assert!(!vs.is_empty());
        let n = vs[0].len();
        for v in vs {
            assert_eq!(v.len(), n, "ragged parameter vectors");
        }
        const CHUNK: usize = 8 * 1024; // 32 KiB of f32 — fits L1
        let mut out = vec![0f32; n];
        let mut start = 0;
        while start < n {
            let end = (start + CHUNK).min(n);
            let out_chunk = &mut out[start..end];
            let m = out_chunk.len();
            let mut k = 0;
            while k + 4 <= vs.len() {
                // Re-slicing to the chunk length lets the bounds checks
                // vanish from the inner loop.
                let s0 = &vs[k].0[start..end][..m];
                let s1 = &vs[k + 1].0[start..end][..m];
                let s2 = &vs[k + 2].0[start..end][..m];
                let s3 = &vs[k + 3].0[start..end][..m];
                let (w0, w1, w2, w3) =
                    (weights[k], weights[k + 1], weights[k + 2], weights[k + 3]);
                for (j, o) in out_chunk.iter_mut().enumerate() {
                    let mut acc = *o + w0 * s0[j];
                    acc += w1 * s1[j];
                    acc += w2 * s2[j];
                    acc += w3 * s3[j];
                    *o = acc;
                }
                k += 4;
            }
            while k < vs.len() {
                let src = &vs[k].0[start..end];
                let w = weights[k];
                for (o, &x) in out_chunk.iter_mut().zip(src) {
                    *o += w * x;
                }
                k += 1;
            }
            start = end;
        }
        ParamVector(out)
    }

    /// Reference K-pass implementation (kept for the §Perf before/after
    /// bench and as a differential-testing oracle for `weighted_sum`).
    pub fn weighted_sum_naive(vs: &[ParamVector], weights: &[f32]) -> ParamVector {
        assert_eq!(vs.len(), weights.len());
        assert!(!vs.is_empty());
        let n = vs[0].len();
        let mut out = vec![0f32; n];
        for (v, &w) in vs.iter().zip(weights) {
            assert_eq!(v.len(), n, "ragged parameter vectors");
            for (o, &x) in out.iter_mut().zip(&v.0) {
                *o += w * x;
            }
        }
        ParamVector(out)
    }

    /// Coordinate-wise trimmed mean: drop the `trim` lowest and highest
    /// values per coordinate, average the rest (robust aggregation).
    pub fn trimmed_mean(vs: &[ParamVector], trim: usize) -> ParamVector {
        assert!(!vs.is_empty());
        assert!(
            2 * trim < vs.len(),
            "trim {trim} leaves no values from {} clients",
            vs.len()
        );
        let n = vs[0].len();
        let mut out = vec![0f32; n];
        let mut column = vec![0f32; vs.len()];
        for i in 0..n {
            for (j, v) in vs.iter().enumerate() {
                column[j] = v.0[i];
            }
            column.sort_by(|a, b| a.total_cmp(b));
            let kept = &column[trim..vs.len() - trim];
            out[i] = kept.iter().sum::<f32>() / kept.len() as f32;
        }
        ParamVector(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pv(xs: &[f32]) -> ParamVector {
        ParamVector::from_vec(xs.to_vec())
    }

    #[test]
    fn norm_and_axpy() {
        let mut a = pv(&[3.0, 4.0]);
        assert!((a.l2_norm() - 5.0).abs() < 1e-12);
        a.add_scaled(&pv(&[1.0, 2.0]), 2.0);
        assert_eq!(a.as_slice(), &[5.0, 8.0]);
        a.scale(0.5);
        assert_eq!(a.as_slice(), &[2.5, 4.0]);
    }

    #[test]
    fn weighted_sum_matches_manual() {
        let out = ParamVector::weighted_sum(
            &[pv(&[1.0, 0.0]), pv(&[0.0, 2.0]), pv(&[1.0, 1.0])],
            &[0.5, 0.25, 0.25],
        );
        assert_eq!(out.as_slice(), &[0.75, 0.75]);
    }

    #[test]
    fn blocked_weighted_sum_matches_naive_across_chunk_boundaries() {
        // Sizes straddling the 8192-element chunk boundary.
        for n in [1usize, 8191, 8192, 8193, 40_000] {
            let vs: Vec<ParamVector> = (0..5)
                .map(|k| {
                    ParamVector::from_vec(
                        (0..n).map(|i| ((i * 7 + k * 13) % 101) as f32 * 0.01).collect(),
                    )
                })
                .collect();
            let w = [0.1, 0.2, 0.3, 0.25, 0.15];
            let a = ParamVector::weighted_sum(&vs, &w);
            let b = ParamVector::weighted_sum_naive(&vs, &w);
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert!((x - y).abs() < 1e-5, "n={n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn trimmed_mean_drops_outliers() {
        let vs = [
            pv(&[1.0]),
            pv(&[1.1]),
            pv(&[0.9]),
            pv(&[100.0]), // malicious
            pv(&[-100.0]),
        ];
        let out = ParamVector::trimmed_mean(&vs, 1);
        assert!((out.as_slice()[0] - 1.0).abs() < 0.1, "{:?}", out);
    }

    #[test]
    #[should_panic]
    fn trimmed_mean_overtrim_panics() {
        ParamVector::trimmed_mean(&[pv(&[1.0]), pv(&[2.0])], 1);
    }

    #[test]
    fn sub() {
        assert_eq!(pv(&[3.0, 2.0]).sub(&pv(&[1.0, 5.0])).as_slice(), &[2.0, -3.0]);
    }

    #[test]
    fn scratch_recycles_without_changing_contents() {
        let scratch = ParamScratch::default();
        let src = pv(&[1.0, 2.0, 3.0]);
        let a = scratch.clone_vector(&src);
        assert_eq!(a, src);
        scratch.recycle(a);
        assert_eq!(scratch.stashed(), 1);
        // The recycled buffer is fully overwritten — longer and shorter
        // sources both come back exact.
        let long = pv(&[9.0; 8]);
        assert_eq!(scratch.clone_vector(&long), long);
        assert_eq!(scratch.stashed(), 0);

        let f = scratch.take_f64_zeroed(5);
        assert_eq!(f, vec![0.0; 5]);
        scratch.recycle_f64(f);
        let f2 = scratch.take_f64_zeroed(2);
        assert_eq!(f2, vec![0.0; 2], "recycled f64 buffer re-zeroed/resized");
        // Handles share one stash.
        let h2 = scratch.clone();
        h2.recycle_f64(f2);
        assert_eq!(scratch.stashed(), 1);
    }
}
