//! Population-scale virtual clients: federations as compact descriptors,
//! instantiated on demand (DESIGN.md §11).
//!
//! The engine historically materialised every client as a live
//! `Box<dyn ClientApp>` held by the server — fine for hundreds of
//! clients, hopeless for millions.  FLUTE (arXiv:2203.13789) and Flower's
//! virtual client engine (arXiv:2007.14390) both showed that scalable FL
//! simulation stores clients as *descriptors* and instantiates them only
//! for the rounds that select them.  This module is that architecture:
//!
//! * [`ClientDescriptor`] — ~24 bytes of per-client state: a hardware
//!   index into a deduplicated profile table, a network tier, the data
//!   shard size, a per-client RNG seed, and an availability-model id.
//! * [`Population`] — the roster.  An **explicit** population stores one
//!   descriptor per client (used below [`DENSE_POPULATION_MAX`], where it
//!   is bit-identical to the materialised fleet by construction); a
//!   **virtual** population stores only the profile table plus generation
//!   parameters — `descriptor(i)` is a pure function of `(seed, i)`, so a
//!   million-client federation costs O(profile table) memory, not
//!   O(population).
//! * [`ClientFactory`] — instantiates the `ClientApp` behind a descriptor
//!   for one round; when the round ends the live object is dropped and
//!   the client exists as its descriptor again.  Clients are stateless
//!   across rounds by construction (`SimClient` holds no mutable state;
//!   `TrainClient` derives everything from its seed and the round
//!   number), which is what makes checkout → fit → drop bit-identical to
//!   keeping the object alive (property-tested in `tests/properties.rs`).
//!
//! `ExperimentBuilder::population(n)` (and the `[population]` config
//! section) routes `Simulated` federations through this layer; the
//! server-side integration is `ServerApp::with_population`.
#![deny(missing_docs)]

use std::sync::Arc;

use crate::data::Dataset;
use crate::hardware::profile::HardwareProfile;
use crate::hardware::sampler::ProfileTable;
use crate::modelcost::WorkloadCost;
use crate::net::{self, NetworkProfile};
use crate::util::rng::Pcg;

use super::client::{ClientApp, ClientId, SimClient, TrainClient};

/// Largest population the engine still runs with the materialised-era
/// algorithms and RNG streams: explicit descriptors, full-pool selection
/// (`Pcg::sample_indices`), dense federation dynamics (eager traces,
/// per-round churn sweeps).  Above it, selection switches to Floyd
/// sampling (`Pcg::sample_distinct_sorted`), dynamics to lazy
/// per-candidate evaluation, and hardware to the deduplicated profile
/// table — O(cohort) per round instead of O(population), at the cost of
/// different (still deterministic) RNG streams.  Bit-identity with the
/// historical engine below this threshold is property-tested in
/// `tests/properties.rs`.
pub const DENSE_POPULATION_MAX: usize = 8192;

/// RNG stream id for per-client network-tier draws — shared with the
/// materialised assembly in `fl::experiment` so the two paths draw
/// identical links.
pub(crate) const NET_STREAM: u64 = 0x4E7;

/// Seed salt separating virtual-descriptor derivation from every other
/// federation stream.
const DESCRIPTOR_SEED_SALT: u64 = 0xDE5C;

/// Compact per-client state: everything needed to instantiate the client
/// for a round.  `Copy` and ~24 bytes, so a million of them would be
/// cheap — and a *virtual* population does not even store them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientDescriptor {
    /// Index into the population's deduplicated [`ProfileTable`].
    pub profile: u32,
    /// Data-shard size (training examples the client holds).
    pub num_examples: u32,
    /// Per-client RNG seed (batch loading, synthetic losses).
    pub seed: u64,
    /// Index into [`net::NET_TIERS`]; `None` = no network model.
    pub network: Option<u8>,
    /// Availability-model id.  The scenario layer currently compiles a
    /// single model per federation, so this is always 0; it is part of
    /// the descriptor so per-client availability classes need no layout
    /// change.
    pub availability: u8,
}

impl ClientDescriptor {
    /// The network link this descriptor's tier resolves to.
    pub fn network_profile(&self) -> Option<NetworkProfile> {
        self.network.map(|t| net::NET_TIERS[t as usize].0)
    }
}

/// Instantiates the live client behind a descriptor for the duration of
/// one round.  The round engine checks clients out through this factory
/// and back in by dropping them — the descriptor *is* the checked-in
/// form.
///
/// `Send` because the concurrent round engine moves instantiated clients
/// to worker threads.
pub trait ClientFactory: Send {
    /// Build the `ClientApp` for client `id` described by `desc`;
    /// `profile` is the resolved entry of the population's profile table.
    fn instantiate(
        &self,
        id: ClientId,
        desc: &ClientDescriptor,
        profile: &HardwareProfile,
    ) -> Box<dyn ClientApp>;
}

/// Factory for timing-only fleets: descriptors become [`SimClient`]s.
/// The population engine's default — a million-client `Simulated`
/// federation instantiates only its per-round cohort.
pub struct SimClientFactory {
    workload: WorkloadCost,
}

impl SimClientFactory {
    /// A factory charging `workload` for every emulated fit.
    pub fn new(workload: WorkloadCost) -> Self {
        SimClientFactory { workload }
    }
}

impl ClientFactory for SimClientFactory {
    fn instantiate(
        &self,
        id: ClientId,
        desc: &ClientDescriptor,
        profile: &HardwareProfile,
    ) -> Box<dyn ClientApp> {
        let mut c = SimClient::new(
            id,
            profile.clone(),
            desc.num_examples as usize,
            self.workload.clone(),
        );
        c.network = desc.network_profile();
        Box::new(c)
    }
}

/// Factory for real-training fleets: descriptors become [`TrainClient`]s
/// over shared data partitions.  The partition index lists are inherently
/// O(total samples) — population-scale federations use
/// [`SimClientFactory`]; this factory serves library users who want the
/// descriptor lifecycle with real PJRT training at moderate sizes.
pub struct TrainClientFactory {
    data: Arc<Dataset>,
    parts: Arc<Vec<Vec<usize>>>,
    workload: WorkloadCost,
}

impl TrainClientFactory {
    /// A factory training on `data`, client `i` holding `parts[i]`.
    pub fn new(data: Arc<Dataset>, parts: Arc<Vec<Vec<usize>>>, workload: WorkloadCost) -> Self {
        TrainClientFactory { data, parts, workload }
    }
}

impl ClientFactory for TrainClientFactory {
    fn instantiate(
        &self,
        id: ClientId,
        desc: &ClientDescriptor,
        profile: &HardwareProfile,
    ) -> Box<dyn ClientApp> {
        let subset = self.data.subset(&self.parts[id as usize]);
        let mut c = TrainClient::new(
            id,
            profile.clone(),
            subset,
            self.workload.clone(),
            desc.seed,
        );
        if let Some(link) = desc.network_profile() {
            c = c.with_network(link);
        }
        Box::new(c)
    }
}

/// How a virtual population assigns profile-table entries to clients.
#[derive(Debug, Clone)]
enum ProfileAssignment {
    /// Weighted draw over the table via a precomputed CDF (survey-sampled
    /// fleets: each distinct profile's weight is its draw count, so the
    /// survey marginals carry over).
    Weighted(Vec<f64>),
    /// Deterministic round-robin over the table (manual profile lists —
    /// note the table is deduplicated, so a manual list with repeats
    /// cycles its *distinct* entries).
    Cycle,
}

#[derive(Debug, Clone)]
enum PopulationKind {
    /// One stored descriptor per client (below-threshold federations,
    /// hand-built rosters, tests).
    Explicit(Vec<ClientDescriptor>),
    /// Descriptors derived on demand: `descriptor(i)` is a pure function
    /// of `(seed, i)` — O(1) stored state per client.
    Virtual {
        len: usize,
        seed: u64,
        samples_per_client: u32,
        network: bool,
        assign: ProfileAssignment,
    },
}

/// A federation roster in O(cohort + profile table) memory: per-client
/// state lives as [`ClientDescriptor`]s (stored or derived), hardware as
/// a deduplicated [`ProfileTable`].
#[derive(Debug, Clone)]
pub struct Population {
    table: ProfileTable,
    kind: PopulationKind,
}

impl Population {
    /// Explicit population mirroring a resolved per-client profile list —
    /// the bit-identity bridge from the materialised engine: descriptors
    /// carry the same per-client seeds (`seed ^ (i << 8)`) and the same
    /// network draws (one shared `NET_STREAM` generator advanced in id
    /// order) the materialised assembly produces, so a factory-built
    /// fleet equals a live one client for client.
    pub fn from_profiles(
        profiles: &[HardwareProfile],
        samples_per_client: usize,
        network: bool,
        seed: u64,
    ) -> Population {
        assert!(!profiles.is_empty(), "a population needs at least one client");
        let mut table = ProfileTable::new();
        let mut net_rng = Pcg::new(seed, NET_STREAM);
        let descriptors = profiles
            .iter()
            .enumerate()
            .map(|(i, p)| ClientDescriptor {
                profile: table.insert(p.clone()),
                num_examples: samples_per_client as u32,
                seed: seed ^ ((i as u64) << 8),
                network: network.then(|| net::sample_network_index(&mut net_rng) as u8),
                availability: 0,
            })
            .collect();
        Population { table, kind: PopulationKind::Explicit(descriptors) }
    }

    /// Explicit population from hand-built descriptors (library users who
    /// manage their own table/descriptor layout).
    pub fn from_descriptors(table: ProfileTable, descriptors: Vec<ClientDescriptor>) -> Population {
        assert!(!descriptors.is_empty(), "a population needs at least one client");
        assert!(
            descriptors.iter().all(|d| (d.profile as usize) < table.len()),
            "descriptor profile index outside the table"
        );
        Population { table, kind: PopulationKind::Explicit(descriptors) }
    }

    /// Virtual population over a survey-sampled profile table: client `i`
    /// draws its profile from the table's weights, its network tier and
    /// seed from a dedicated per-client stream — all pure functions of
    /// `(seed, i)`, nothing stored per client.
    pub fn virtual_survey(
        seed: u64,
        len: usize,
        table: ProfileTable,
        samples_per_client: usize,
        network: bool,
    ) -> Population {
        assert!(len > 0, "a population needs at least one client");
        assert!(!table.is_empty(), "virtual population over an empty profile table");
        let cdf = table.cdf();
        Population {
            table,
            kind: PopulationKind::Virtual {
                len,
                seed,
                samples_per_client: samples_per_client as u32,
                network,
                assign: ProfileAssignment::Weighted(cdf),
            },
        }
    }

    /// Virtual population cycling a (deduplicated) manual profile table:
    /// client `i` uses table entry `i % table.len()`.
    pub fn virtual_cycle(
        seed: u64,
        len: usize,
        table: ProfileTable,
        samples_per_client: usize,
        network: bool,
    ) -> Population {
        assert!(len > 0, "a population needs at least one client");
        assert!(!table.is_empty(), "virtual population over an empty profile table");
        Population {
            table,
            kind: PopulationKind::Virtual {
                len,
                seed,
                samples_per_client: samples_per_client as u32,
                network,
                assign: ProfileAssignment::Cycle,
            },
        }
    }

    /// Federation size.
    pub fn len(&self) -> usize {
        match &self.kind {
            PopulationKind::Explicit(d) => d.len(),
            PopulationKind::Virtual { len, .. } => *len,
        }
    }

    /// True for the (unreachable by construction) zero-client roster.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The deduplicated hardware table descriptors index into.
    pub fn profile_table(&self) -> &ProfileTable {
        &self.table
    }

    /// Resolve a descriptor's profile index.
    pub fn profile(&self, idx: u32) -> &HardwareProfile {
        self.table.profile(idx)
    }

    /// Client `i`'s descriptor — a lookup for explicit populations, a
    /// pure derivation for virtual ones (query-order independent;
    /// property-tested).
    pub fn descriptor(&self, i: usize) -> ClientDescriptor {
        match &self.kind {
            PopulationKind::Explicit(d) => d[i],
            PopulationKind::Virtual { len, seed, samples_per_client, network, assign } => {
                assert!(i < *len, "client {i} outside population of {len}");
                let mut rng = Pcg::new(seed ^ DESCRIPTOR_SEED_SALT, i as u64);
                let profile = match assign {
                    ProfileAssignment::Weighted(cdf) => {
                        let total = *cdf.last().expect("non-empty table");
                        let x = rng.f64() * total;
                        cdf.partition_point(|&c| c < x).min(cdf.len() - 1) as u32
                    }
                    ProfileAssignment::Cycle => (i % self.table.len()) as u32,
                };
                ClientDescriptor {
                    profile,
                    num_examples: *samples_per_client,
                    seed: rng.next_u64(),
                    network: network.then(|| net::sample_network_index(&mut rng) as u8),
                    availability: 0,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::profile::preset;
    use crate::modelcost::small_cnn;

    fn profiles() -> Vec<HardwareProfile> {
        // Cycled list with a repeat: the table must deduplicate to 2.
        vec![
            preset("gtx-1060").unwrap(),
            preset("budget-2019").unwrap(),
            preset("gtx-1060").unwrap(),
        ]
    }

    #[test]
    fn descriptor_is_compact() {
        assert!(
            std::mem::size_of::<ClientDescriptor>() <= 32,
            "descriptor grew past its compactness budget: {} bytes",
            std::mem::size_of::<ClientDescriptor>()
        );
    }

    #[test]
    fn from_profiles_dedupes_and_preserves_assignment() {
        let pop = Population::from_profiles(&profiles(), 64, false, 7);
        assert_eq!(pop.len(), 3);
        assert_eq!(pop.profile_table().len(), 2, "repeat profile deduplicated");
        let d0 = pop.descriptor(0);
        let d2 = pop.descriptor(2);
        assert_eq!(d0.profile, d2.profile, "same preset, same table entry");
        assert_ne!(d0.seed, d2.seed, "per-client seeds differ");
        assert_eq!(pop.profile(d0.profile).gpu.slug, "gtx-1060");
        assert_eq!(pop.profile(pop.descriptor(1).profile).name, profiles()[1].name);
        assert!(d0.network.is_none());
    }

    #[test]
    fn from_profiles_network_matches_the_materialized_stream() {
        let pop = Population::from_profiles(&profiles(), 64, true, 11);
        let mut net_rng = Pcg::new(11, NET_STREAM);
        for i in 0..pop.len() {
            let expected = net::sample_network(&mut net_rng);
            assert_eq!(
                pop.descriptor(i).network_profile(),
                Some(expected),
                "client {i} link diverged from the materialized draw order"
            );
        }
    }

    #[test]
    fn virtual_descriptors_are_query_order_independent() {
        let mut table = ProfileTable::new();
        for p in profiles() {
            table.insert(p);
        }
        let pop = Population::virtual_survey(3, 10_000, table.clone(), 32, true);
        let again = Population::virtual_survey(3, 10_000, table, 32, true);
        // Forward on one instance, scattered on the other.
        let forward: Vec<ClientDescriptor> = (0..50).map(|i| pop.descriptor(i)).collect();
        for i in (0..50usize).rev().step_by(3) {
            let _ = again.descriptor(i * 100);
        }
        for (i, d) in forward.iter().enumerate() {
            assert_eq!(*d, again.descriptor(i), "client {i}");
            assert_eq!(*d, pop.descriptor(i), "client {i} re-query");
        }
        // In-range profile indices and populated fields.
        for i in [0usize, 1, 9_999] {
            let d = pop.descriptor(i);
            assert!((d.profile as usize) < pop.profile_table().len());
            assert_eq!(d.num_examples, 32);
            assert!(d.network.is_some());
        }
    }

    #[test]
    fn virtual_cycle_assigns_round_robin() {
        let mut table = ProfileTable::new();
        table.insert(preset("gtx-1060").unwrap());
        table.insert(preset("budget-2019").unwrap());
        let pop = Population::virtual_cycle(0, 100, table, 16, false);
        for i in 0..10 {
            assert_eq!(pop.descriptor(i).profile as usize, i % 2);
        }
    }

    #[test]
    fn sim_factory_builds_the_described_client() {
        let pop = Population::from_profiles(&profiles(), 48, true, 5);
        let factory = SimClientFactory::new(small_cnn());
        let d = pop.descriptor(1);
        let client = factory.instantiate(1, &d, pop.profile(d.profile));
        assert_eq!(client.id(), 1);
        assert_eq!(client.num_examples(), 48);
        assert_eq!(client.profile().name, profiles()[1].name);
        assert_eq!(client.network().copied(), d.network_profile());
    }
}
