//! Client selection: which clients participate in a round.

use crate::util::rng::Pcg;

/// Selection policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Selection {
    /// Every client, every round.
    All,
    /// A uniform random fraction (Flower's default behaviour).
    Fraction(f64),
    /// A fixed number of uniformly random clients.
    Count(usize),
}

/// Deterministic, seeded client selector.
pub struct ClientManager {
    rng: Pcg,
    pub selection: Selection,
}

impl ClientManager {
    pub fn new(seed: u64, selection: Selection) -> Self {
        ClientManager { rng: Pcg::new(seed, 0x5E1E), selection }
    }

    /// Indices of the clients participating in this round.
    pub fn select(&mut self, num_clients: usize) -> Vec<usize> {
        assert!(num_clients > 0);
        match self.selection {
            Selection::All => (0..num_clients).collect(),
            Selection::Fraction(f) => {
                assert!((0.0..=1.0).contains(&f), "fraction {f}");
                let k = ((num_clients as f64 * f).round() as usize).clamp(1, num_clients);
                let mut v = self.rng.sample_indices(num_clients, k);
                v.sort();
                v
            }
            Selection::Count(k) => {
                let k = k.clamp(1, num_clients);
                let mut v = self.rng.sample_indices(num_clients, k);
                v.sort();
                v
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_selects_everyone() {
        let mut m = ClientManager::new(0, Selection::All);
        assert_eq!(m.select(5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fraction_selects_expected_count() {
        let mut m = ClientManager::new(1, Selection::Fraction(0.4));
        let s = m.select(10);
        assert_eq!(s.len(), 4);
        assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
    }

    #[test]
    fn count_clamped() {
        let mut m = ClientManager::new(2, Selection::Count(100));
        assert_eq!(m.select(5).len(), 5);
        let mut m0 = ClientManager::new(2, Selection::Count(0));
        assert_eq!(m0.select(5).len(), 1, "at least one client");
    }

    #[test]
    fn deterministic_sequence_per_seed() {
        let mut a = ClientManager::new(7, Selection::Count(3));
        let mut b = ClientManager::new(7, Selection::Count(3));
        for _ in 0..5 {
            assert_eq!(a.select(20), b.select(20));
        }
    }

    #[test]
    fn rounds_differ() {
        let mut m = ClientManager::new(7, Selection::Count(3));
        let r1 = m.select(20);
        let r2 = m.select(20);
        // With overwhelming probability the two rounds differ.
        assert!(r1 != r2 || m.select(20) != r1);
    }
}
