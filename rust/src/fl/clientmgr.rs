//! Client selection and per-round participation bookkeeping: which clients
//! participate in a round, and what each contributed once the round's
//! completion stream has been consumed.

use crate::sched::Durations;
use crate::util::rng::Pcg;

use super::client::{ClientId, FitResult};
use super::history::FailureRecord;

/// Selection policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Selection {
    /// Every client, every round.
    All,
    /// A uniform random fraction (Flower's default behaviour).
    Fraction(f64),
    /// A fixed number of uniformly random clients.
    Count(usize),
}

/// Deterministic, seeded client selector.
pub struct ClientManager {
    rng: Pcg,
    pub selection: Selection,
}

impl ClientManager {
    pub fn new(seed: u64, selection: Selection) -> Self {
        ClientManager { rng: Pcg::new(seed, 0x5E1E), selection }
    }

    /// Indices of the clients participating in this round.
    pub fn select(&mut self, num_clients: usize) -> Vec<usize> {
        assert!(num_clients > 0);
        let everyone: Vec<usize> = (0..num_clients).collect();
        self.select_from(&everyone)
    }

    /// Participants drawn from an eligibility pool (the federation-dynamics
    /// layer filters out non-members and offline clients before each
    /// round).  With the full pool this draws exactly the same RNG stream
    /// as [`ClientManager::select`], so static federations are untouched.
    pub fn select_from(&mut self, eligible: &[usize]) -> Vec<usize> {
        assert!(!eligible.is_empty(), "select_from on an empty pool");
        match self.selection {
            Selection::All => eligible.to_vec(),
            Selection::Fraction(f) => {
                assert!((0.0..=1.0).contains(&f), "fraction {f}");
                let k =
                    ((eligible.len() as f64 * f).round() as usize).clamp(1, eligible.len());
                self.pick(eligible, k)
            }
            Selection::Count(k) => {
                let k = k.clamp(1, eligible.len());
                self.pick(eligible, k)
            }
        }
    }

    fn pick(&mut self, eligible: &[usize], k: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .rng
            .sample_indices(eligible.len(), k)
            .into_iter()
            .map(|i| eligible[i])
            .collect();
        v.sort();
        v
    }
}

/// Per-round participation ledger: the round loop consumes a completion
/// stream of fit outcomes (it no longer holds a `Vec<FitResult>`), so the
/// scalar bookkeeping a `RoundRecord` needs is folded here, O(clients)
/// scalars instead of O(clients x params) vectors.
///
/// `record_success` must be called in selection order (the round engine's
/// reorder buffer guarantees this) so the f32 loss fold is bit-identical
/// to the old collect-then-sum path.
#[derive(Debug, Default)]
pub struct RoundLedger {
    pub selected: Vec<u32>,
    pub failures: Vec<FailureRecord>,
    /// Per-client (id, emulated fit + comm seconds), successes only, in
    /// selection order — the scheduler's input.
    pub durations: Durations,
    loss_weighted: f32,
    total_examples: usize,
}

impl RoundLedger {
    pub fn new(selected: Vec<u32>) -> Self {
        RoundLedger { selected, ..Default::default() }
    }

    /// Fold one finished client's scalars in (the params go to the
    /// aggregation accumulator, not here).
    pub fn record_success(&mut self, r: &FitResult) {
        self.durations.push((r.client, r.emu.emu_total_s + r.comm_s));
        self.loss_weighted += r.mean_loss * r.num_examples as f32;
        self.total_examples += r.num_examples;
    }

    pub fn record_failure(&mut self, client: ClientId, reason: String) {
        self.failures.push(FailureRecord { client, reason });
    }

    pub fn successes(&self) -> usize {
        self.durations.len()
    }

    pub fn total_examples(&self) -> usize {
        self.total_examples
    }

    /// Example-weighted mean training loss (NaN if nothing succeeded).
    pub fn train_loss(&self) -> f32 {
        self.loss_weighted / self.total_examples as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emu::FitReport;
    use crate::fl::params::ParamVector;

    #[test]
    fn ledger_folds_scalars_in_selection_order() {
        let mut ledger = RoundLedger::new(vec![0, 1, 2]);
        let r = |client: u32, loss: f32, n: usize, emu_s: f64| FitResult {
            client,
            params: ParamVector::zeros(1),
            num_examples: n,
            mean_loss: loss,
            emu: FitReport::synthetic(1, 1, emu_s),
            comm_s: 1.0,
        };
        ledger.record_success(&r(0, 2.0, 10, 3.0));
        ledger.record_success(&r(2, 1.0, 30, 5.0));
        ledger.record_failure(1, "GPU OOM".into());
        assert_eq!(ledger.successes(), 2);
        assert_eq!(ledger.total_examples(), 40);
        assert_eq!(ledger.durations, vec![(0, 4.0), (2, 6.0)]);
        // (2*10 + 1*30) / 40
        assert!((ledger.train_loss() - 1.25).abs() < 1e-6);
        assert_eq!(ledger.failures.len(), 1);
    }

    #[test]
    fn all_selects_everyone() {
        let mut m = ClientManager::new(0, Selection::All);
        assert_eq!(m.select(5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fraction_selects_expected_count() {
        let mut m = ClientManager::new(1, Selection::Fraction(0.4));
        let s = m.select(10);
        assert_eq!(s.len(), 4);
        assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
    }

    #[test]
    fn count_clamped() {
        let mut m = ClientManager::new(2, Selection::Count(100));
        assert_eq!(m.select(5).len(), 5);
        let mut m0 = ClientManager::new(2, Selection::Count(0));
        assert_eq!(m0.select(5).len(), 1, "at least one client");
    }

    #[test]
    fn deterministic_sequence_per_seed() {
        let mut a = ClientManager::new(7, Selection::Count(3));
        let mut b = ClientManager::new(7, Selection::Count(3));
        for _ in 0..5 {
            assert_eq!(a.select(20), b.select(20));
        }
    }

    #[test]
    fn select_from_full_pool_matches_select() {
        let mut a = ClientManager::new(3, Selection::Fraction(0.5));
        let mut b = ClientManager::new(3, Selection::Fraction(0.5));
        let pool: Vec<usize> = (0..12).collect();
        for _ in 0..5 {
            assert_eq!(a.select(12), b.select_from(&pool));
        }
    }

    #[test]
    fn select_from_only_returns_eligible_clients() {
        let mut m = ClientManager::new(5, Selection::Count(3));
        let pool = vec![1, 4, 7, 9, 11];
        for _ in 0..10 {
            let s = m.select_from(&pool);
            assert_eq!(s.len(), 3);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
            assert!(s.iter().all(|c| pool.contains(c)), "{s:?}");
        }
        // All: the pool itself.
        let mut all = ClientManager::new(5, Selection::All);
        assert_eq!(all.select_from(&pool), pool);
    }

    #[test]
    fn rounds_differ() {
        let mut m = ClientManager::new(7, Selection::Count(3));
        let r1 = m.select(20);
        let r2 = m.select(20);
        // With overwhelming probability the two rounds differ.
        assert!(r1 != r2 || m.select(20) != r1);
    }
}
