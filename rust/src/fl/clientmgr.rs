//! Client selection and per-round participation bookkeeping: which clients
//! participate in a round, and what each contributed once the round's
//! completion stream has been consumed.

use std::collections::BTreeSet;

use crate::sched::Durations;
use crate::util::rng::Pcg;

use super::client::{ClientId, FitResult};
use super::history::FailureRecord;
use super::population::DENSE_POPULATION_MAX;

/// Selection policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Selection {
    /// Every client, every round.
    All,
    /// A uniform random fraction (Flower's default behaviour).
    Fraction(f64),
    /// A fixed number of uniformly random clients.
    Count(usize),
}

/// Consecutive rejected candidates [`ClientManager::select_filtered`]
/// tolerates (per needed participant) before falling back to one full
/// eligibility sweep.
const REJECTION_BUDGET_PER_SLOT: usize = 64;

/// The cohort size a policy seats over a pool of `n` clients (`None` =
/// everyone) — the single definition behind `select`, `select_from` and
/// `select_filtered`, whose agreement the stream-identity contracts
/// depend on.
fn cohort_k(selection: Selection, n: usize) -> Option<usize> {
    match selection {
        Selection::All => None,
        Selection::Fraction(f) => {
            assert!((0.0..=1.0).contains(&f), "fraction {f}");
            Some(((n as f64 * f).round() as usize).clamp(1, n))
        }
        Selection::Count(k) => Some(k.clamp(1, n)),
    }
}

/// Deterministic, seeded client selector.
pub struct ClientManager {
    rng: Pcg,
    pub selection: Selection,
    /// Cached identity pool for the static path ([`ClientManager::select`]):
    /// built once and reused every round, invalidated only when the
    /// federation size changes.  (Dynamic federations churn membership
    /// through [`ClientManager::select_from`] /
    /// [`ClientManager::select_filtered`] and never touch this.)
    pool: Vec<usize>,
    /// Owns the most recent sampled cohort (the storage behind the slice
    /// [`ClientManager::select`] returns).
    scratch: Vec<usize>,
}

impl ClientManager {
    pub fn new(seed: u64, selection: Selection) -> Self {
        ClientManager {
            rng: Pcg::new(seed, 0x5E1E),
            selection,
            pool: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Snapshot the selection RNG's raw state for a checkpoint
    /// (`durable::checkpoint`).  The stream cannot be replayed the way
    /// dynamics churn can — its draw count depends on per-round cohort
    /// sizes — so resume restores it verbatim.
    pub fn rng_state(&self) -> (u64, u64) {
        self.rng.state_parts()
    }

    /// Restore the selection RNG from [`ClientManager::rng_state`].
    pub fn restore_rng(&mut self, state: u64, inc: u64) {
        self.rng = Pcg::from_state_parts(state, inc);
    }

    /// Indices of the clients participating in this round.
    ///
    /// The static path: `Selection::All` returns the cached identity pool
    /// (no per-round allocation at all); sampled selections reuse one
    /// scratch buffer.  Below [`DENSE_POPULATION_MAX`] the sampled RNG
    /// stream is bit-identical to the historical
    /// `select_from(&(0..n).collect())` (property-tested); above it,
    /// Floyd's algorithm draws the cohort in O(k log k) without ever
    /// materialising the population.
    pub fn select(&mut self, num_clients: usize) -> &[usize] {
        assert!(num_clients > 0);
        let k = match cohort_k(self.selection, num_clients) {
            None => {
                if self.pool.len() != num_clients {
                    self.pool.clear();
                    self.pool.extend(0..num_clients);
                }
                return &self.pool;
            }
            Some(k) => k,
        };
        if num_clients <= DENSE_POPULATION_MAX {
            // Historical stream: partial Fisher–Yates over the identity
            // pool, then sort — exactly what the materialised engine drew.
            let mut v = self.rng.sample_indices(num_clients, k);
            v.sort_unstable();
            self.scratch = v;
        } else {
            let v = self.rng.sample_distinct_sorted(num_clients, k);
            self.scratch = v;
        }
        &self.scratch
    }

    /// Participants drawn from an eligibility pool (the federation-dynamics
    /// layer filters out non-members and offline clients before each
    /// round).  With the full pool this draws exactly the same RNG stream
    /// as [`ClientManager::select`], so static federations are untouched.
    pub fn select_from(&mut self, eligible: &[usize]) -> Vec<usize> {
        assert!(!eligible.is_empty(), "select_from on an empty pool");
        match cohort_k(self.selection, eligible.len()) {
            None => eligible.to_vec(),
            Some(k) => self.pick(eligible, k),
        }
    }

    fn pick(&mut self, eligible: &[usize], k: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .rng
            .sample_indices(eligible.len(), k)
            .into_iter()
            .map(|i| eligible[i])
            .collect();
        v.sort();
        v
    }

    /// Participants drawn under *lazy* eligibility: candidates are
    /// sampled uniformly from the whole population and tested one at a
    /// time, so no O(population) eligible pool is ever materialised.
    /// This is the population engine's path above
    /// [`DENSE_POPULATION_MAX`] (`sched::dynamics` evaluates membership
    /// and availability per candidate on demand).
    ///
    /// Semantics vs [`ClientManager::select_from`]:
    /// * Conditioned on the eligible set, rejection sampling is still
    ///   uniform over it — only the RNG stream differs.
    /// * `Selection::Fraction` resolves against the *population* size
    ///   (the eligible count is unknown without a sweep, which is the
    ///   cost this path exists to avoid).
    /// * `Selection::All` inherently needs the sweep and performs it.
    /// * A starved federation (rejections exhaust the miss budget) falls
    ///   back to one O(population) sweep; if fewer eligible clients exist
    ///   than requested, all of them are returned — possibly none, which
    ///   the server records as a skipped round.
    ///
    /// Returned cohort is sorted and distinct.  Deterministic per seed:
    /// every draw comes from this manager's stream, and `eligible` must
    /// be a pure function of the candidate for a given round (the
    /// dynamics layer's traces are).
    pub fn select_filtered(
        &mut self,
        population: usize,
        eligible: &mut dyn FnMut(usize) -> bool,
    ) -> Vec<usize> {
        assert!(population > 0);
        let k = match cohort_k(self.selection, population) {
            None => return (0..population).filter(|&i| eligible(i)).collect(),
            Some(k) => k,
        };
        let mut chosen: BTreeSet<usize> = BTreeSet::new();
        let budget = REJECTION_BUDGET_PER_SLOT * k + 64;
        let mut misses = 0usize;
        while chosen.len() < k && misses < budget {
            let i = self.rng.below(population);
            if chosen.contains(&i) || !eligible(i) {
                misses += 1;
            } else {
                chosen.insert(i);
            }
        }
        if chosen.len() < k {
            // Sweep fallback: the eligible fraction is (or looks) tiny, so
            // one full pass settles how many participants actually exist.
            let rest: Vec<usize> = (0..population)
                .filter(|&i| !chosen.contains(&i) && eligible(i))
                .collect();
            let need = k - chosen.len();
            if rest.len() <= need {
                chosen.extend(rest);
            } else {
                for j in self.rng.sample_distinct_sorted(rest.len(), need) {
                    chosen.insert(rest[j]);
                }
            }
        }
        chosen.into_iter().collect()
    }
}

/// Per-round participation ledger: the round loop consumes a completion
/// stream of fit outcomes (it no longer holds a `Vec<FitResult>`), so the
/// scalar bookkeeping a `RoundRecord` needs is folded here, O(clients)
/// scalars instead of O(clients x params) vectors.
///
/// `record_success` must be called in selection order (the round engine's
/// reorder buffer guarantees this) so the f32 loss fold is bit-identical
/// to the old collect-then-sum path.
#[derive(Debug, Default)]
pub struct RoundLedger {
    pub selected: Vec<u32>,
    pub failures: Vec<FailureRecord>,
    /// Per-client (id, emulated fit + comm seconds), successes only, in
    /// selection order — the scheduler's input.
    pub durations: Durations,
    loss_weighted: f32,
    total_examples: usize,
}

impl RoundLedger {
    pub fn new(selected: Vec<u32>) -> Self {
        RoundLedger { selected, ..Default::default() }
    }

    /// Fold one finished client's scalars in (the params go to the
    /// aggregation accumulator, not here).
    pub fn record_success(&mut self, r: &FitResult) {
        self.durations.push((r.client, r.emu.emu_total_s + r.comm_s));
        self.loss_weighted += r.mean_loss * r.num_examples as f32;
        self.total_examples += r.num_examples;
    }

    pub fn record_failure(&mut self, client: ClientId, reason: String) {
        self.failures.push(FailureRecord { client, reason });
    }

    pub fn successes(&self) -> usize {
        self.durations.len()
    }

    pub fn total_examples(&self) -> usize {
        self.total_examples
    }

    /// Example-weighted mean training loss (NaN if nothing succeeded).
    pub fn train_loss(&self) -> f32 {
        self.loss_weighted / self.total_examples as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emu::FitReport;
    use crate::fl::params::ParamVector;

    #[test]
    fn ledger_folds_scalars_in_selection_order() {
        let mut ledger = RoundLedger::new(vec![0, 1, 2]);
        let r = |client: u32, loss: f32, n: usize, emu_s: f64| FitResult {
            client,
            params: ParamVector::zeros(1),
            num_examples: n,
            mean_loss: loss,
            emu: FitReport::synthetic(1, 1, emu_s),
            comm_s: 1.0,
        };
        ledger.record_success(&r(0, 2.0, 10, 3.0));
        ledger.record_success(&r(2, 1.0, 30, 5.0));
        ledger.record_failure(1, "GPU OOM".into());
        assert_eq!(ledger.successes(), 2);
        assert_eq!(ledger.total_examples(), 40);
        assert_eq!(ledger.durations, vec![(0, 4.0), (2, 6.0)]);
        // (2*10 + 1*30) / 40
        assert!((ledger.train_loss() - 1.25).abs() < 1e-6);
        assert_eq!(ledger.failures.len(), 1);
    }

    #[test]
    fn all_selects_everyone() {
        let mut m = ClientManager::new(0, Selection::All);
        assert_eq!(m.select(5).to_vec(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn all_path_reuses_the_cached_pool() {
        let mut m = ClientManager::new(0, Selection::All);
        let ptr = m.select(6).as_ptr() as usize;
        for _ in 0..5 {
            assert_eq!(m.select(6).as_ptr() as usize, ptr, "pool reallocated");
        }
        // Size change invalidates the cache...
        assert_eq!(m.select(4).to_vec(), vec![0, 1, 2, 3]);
        // ...and the pool settles again at the new size.
        let ptr = m.select(4).as_ptr() as usize;
        assert_eq!(m.select(4).as_ptr() as usize, ptr);
    }

    #[test]
    fn fraction_selects_expected_count() {
        let mut m = ClientManager::new(1, Selection::Fraction(0.4));
        let s = m.select(10);
        assert_eq!(s.len(), 4);
        assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
    }

    #[test]
    fn count_clamped() {
        let mut m = ClientManager::new(2, Selection::Count(100));
        assert_eq!(m.select(5).len(), 5);
        let mut m0 = ClientManager::new(2, Selection::Count(0));
        assert_eq!(m0.select(5).len(), 1, "at least one client");
    }

    #[test]
    fn deterministic_sequence_per_seed() {
        let mut a = ClientManager::new(7, Selection::Count(3));
        let mut b = ClientManager::new(7, Selection::Count(3));
        for _ in 0..5 {
            assert_eq!(a.select(20).to_vec(), b.select(20).to_vec());
        }
    }

    #[test]
    fn select_from_full_pool_matches_select() {
        let mut a = ClientManager::new(3, Selection::Fraction(0.5));
        let mut b = ClientManager::new(3, Selection::Fraction(0.5));
        let pool: Vec<usize> = (0..12).collect();
        for _ in 0..5 {
            assert_eq!(a.select(12).to_vec(), b.select_from(&pool));
        }
    }

    #[test]
    fn population_scale_select_is_o_k_and_valid() {
        let n = DENSE_POPULATION_MAX * 100;
        let mut m = ClientManager::new(9, Selection::Count(64));
        for _ in 0..3 {
            let s = m.select(n).to_vec();
            assert_eq!(s.len(), 64);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
            assert!(s.iter().all(|&i| i < n));
        }
        // Fraction resolves against the population above threshold too.
        let mut f = ClientManager::new(9, Selection::Fraction(0.0001));
        assert_eq!(f.select(1_000_000).len(), 100);
    }

    #[test]
    fn select_filtered_draws_only_eligible_distinct_sorted() {
        let mut m = ClientManager::new(5, Selection::Count(8));
        let mut probes = 0usize;
        let s = m.select_filtered(10_000, &mut |i| {
            probes += 1;
            i % 3 == 0
        });
        assert_eq!(s.len(), 8);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.iter().all(|&i| i % 3 == 0));
        assert!(
            probes < 10_000,
            "lazy selection swept the population ({probes} probes)"
        );
        // Deterministic per seed.
        let mut m2 = ClientManager::new(5, Selection::Count(8));
        assert_eq!(s, m2.select_filtered(10_000, &mut |i| i % 3 == 0));
    }

    #[test]
    fn select_filtered_starved_pool_returns_every_eligible_client() {
        // Only 3 eligible clients for Count(8): the sweep fallback finds
        // exactly those three.
        let mut m = ClientManager::new(1, Selection::Count(8));
        let s = m.select_filtered(50_000, &mut |i| i == 7 || i == 11_000 || i == 42_000);
        assert_eq!(s, vec![7, 11_000, 42_000]);
        // Nobody eligible: empty cohort (the server skips the round).
        let mut m = ClientManager::new(1, Selection::Count(8));
        assert!(m.select_filtered(50_000, &mut |_| false).is_empty());
        // All: the full eligible sweep.
        let mut all = ClientManager::new(1, Selection::All);
        assert_eq!(all.select_filtered(10, &mut |i| i % 2 == 0), vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn select_from_only_returns_eligible_clients() {
        let mut m = ClientManager::new(5, Selection::Count(3));
        let pool = vec![1, 4, 7, 9, 11];
        for _ in 0..10 {
            let s = m.select_from(&pool);
            assert_eq!(s.len(), 3);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
            assert!(s.iter().all(|c| pool.contains(c)), "{s:?}");
        }
        // All: the pool itself.
        let mut all = ClientManager::new(5, Selection::All);
        assert_eq!(all.select_from(&pool), pool);
    }

    #[test]
    fn rounds_differ() {
        let mut m = ClientManager::new(7, Selection::Count(3));
        let r1 = m.select(20);
        let r2 = m.select(20);
        // With overwhelming probability the two rounds differ.
        assert!(r1 != r2 || m.select(20) != r1);
    }
}
