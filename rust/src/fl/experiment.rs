//! The library-first experiment API: a validated-at-build
//! [`ExperimentBuilder`] façade over the whole engine.
//!
//! ```text
//! Experiment::builder()          — set components in ANY order
//!     .clients(8).rounds(10)
//!     .strategy("fedprox")       — resolved through fl::strategy registry
//!     .scenario_named("high-churn")
//!     .workers(4)
//!     .build()?                  — cross-component constraints checked ONCE
//!     .run()?                    — -> ExperimentReport
//! ```
//!
//! `build()` resolves names through the component registries
//! (`fl::strategy`, `sched`), validates cross-component constraints
//! (strategy participant bounds, selection fractions, scenario values,
//! host-feasible hardware) and resolves the federation's hardware — so a
//! misconfigured experiment fails before any data is generated or any
//! artifact is loaded.  `run()` then assembles data, clients, server and
//! clock exactly as the historical `launch()` path did: for any valid
//! configuration the two produce **bit-identical** schedules, clocks and
//! aggregates (asserted in `tests/experiment_api.rs`), and `launch()`
//! itself is now a thin wrapper over this type.
//!
//! See DESIGN.md §10 for the builder lifecycle and the event flow.
#![deny(missing_docs)]

use std::path::PathBuf;
use std::sync::Arc;

use crate::data::{generate, partition, Dataset, PartitionScheme, SyntheticConfig};
use crate::durable::{LogMeta, RunDurability};
use crate::emu::{ClockMode, VirtualClock};
use crate::error::{ConfigError, FlError};
use crate::hardware::profile::HardwareProfile;
use crate::net::sample_network;
use crate::netsim::{NetSim, NetSimConfig, NETSIM_PRESETS};
use crate::obs::{MetricsHub, MetricsObserver, PhaseRecorder, RunMetrics};
use crate::runtime::ModelExecutor;
use crate::sched::{self, Scheduler, Trace};
use crate::util::cfg::Cfg;
use crate::util::json::Json;
use crate::util::rng::Pcg;

use super::attack::{Attack, AttackConfig, ATTACK_PRESETS};
use super::client::{ClientApp, FitConfig, SimClient, TrainClient};
use super::clientmgr::Selection;
use super::events::{FlObserver, ProgressLogger};
use super::history::History;
use super::launcher::{
    resolve_hardware, resolve_profile_table, HardwareSource, LaunchOptions, PopulationOptions,
    TimingWorkload,
};
use super::params::ParamVector;
use super::population::{
    Population, SimClientFactory, DENSE_POPULATION_MAX, NET_STREAM,
};
use super::scenario::Scenario;
use super::server::{ServerApp, ServerConfig};
use super::strategy::{FoldPlan, Krum, Strategy, TrimmedMean};

/// How client fits execute.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ExecutionMode {
    /// Real AOT/PJRT training (`TrainClient`); needs the artifact
    /// directory.  The paper's default.
    Real,
    /// Timing-only federation (`SimClient`): no artifacts, no executor —
    /// scheduling, dynamics, aggregation and history all behave as usual
    /// over `param_dim`-sized synthetic updates.  For sweeps, examples and
    /// CI.
    Simulated {
        /// Length of the synthetic parameter vector.
        param_dim: usize,
    },
}

/// Builds an [`Experiment`].  Every setter may be called in any order;
/// nothing is resolved until [`ExperimentBuilder::build`].
pub struct ExperimentBuilder {
    opts: LaunchOptions,
    scenario_name: Option<String>,
    scheduler_name: Option<String>,
    netsim_name: Option<String>,
    attack_name: Option<String>,
    strategy_override: Option<Box<dyn Strategy>>,
    observers: Vec<Box<dyn FlObserver>>,
    mode: ExecutionMode,
    progress: bool,
    metrics: bool,
    permissive: bool,
}

impl Default for ExperimentBuilder {
    fn default() -> Self {
        ExperimentBuilder {
            opts: LaunchOptions::default(),
            scenario_name: None,
            scheduler_name: None,
            netsim_name: None,
            attack_name: None,
            strategy_override: None,
            observers: Vec::new(),
            mode: ExecutionMode::Real,
            progress: false,
            metrics: false,
            permissive: false,
        }
    }
}

impl ExperimentBuilder {
    /// Start from existing [`LaunchOptions`] (the legacy shim entrypoint).
    ///
    /// Builders created this way are **permissive**: the historical
    /// `launch()` path enforced no build-time sanity or strategy
    /// participant bounds, so drop-in callers keep the old behaviour —
    /// degenerate configurations fail (or run) exactly where they used
    /// to, at run time.  Call [`ExperimentBuilder::strict`] to opt back
    /// into full validation.
    pub fn from_options(opts: LaunchOptions) -> Self {
        ExperimentBuilder { opts, permissive: true, ..Default::default() }
    }

    /// Start from a parsed federation config file.
    pub fn from_cfg(cfg: &Cfg) -> Result<Self, ConfigError> {
        Ok(Self::from_options(LaunchOptions::from_cfg(cfg)?))
    }

    /// Federation size (total clients).  On a builder with a population
    /// axis set, this also resizes the population — the two are one
    /// number.
    pub fn clients(mut self, n: usize) -> Self {
        self.opts.clients = n;
        if let Some(p) = &mut self.opts.population {
            p.size = n;
        }
        self
    }

    /// Population-scale federation: `n` clients stored as compact
    /// descriptors and instantiated per round through the client factory
    /// (DESIGN.md §11).  Requires [`ExperimentBuilder::simulated`] —
    /// `build()` rejects the combination with real training.  Below
    /// `fl::population::DENSE_POPULATION_MAX` the run is bit-identical to
    /// the materialised fleet; above it, selection and dynamics switch to
    /// the O(cohort) lazy algorithms, so a 1,000,000-client federation
    /// with `Selection::Count(64)` runs in memory proportional to the
    /// cohort plus the profile table.
    pub fn population(mut self, n: usize) -> Self {
        self.opts.population = Some(PopulationOptions::of_size(n));
        self.opts.clients = n;
        self
    }

    /// Full population options (size + profile-table draws).
    pub fn population_options(mut self, opts: PopulationOptions) -> Self {
        self.opts.clients = opts.size;
        self.opts.population = Some(opts);
        self
    }

    /// Number of federated rounds.
    pub fn rounds(mut self, n: u32) -> Self {
        self.opts.rounds = n;
        self
    }

    /// Training samples per client partition.
    pub fn samples_per_client(mut self, n: usize) -> Self {
        self.opts.samples_per_client = n;
        self
    }

    /// Held-out centralised evaluation set size.
    pub fn eval_samples(mut self, n: usize) -> Self {
        self.opts.eval_samples = n;
        self
    }

    /// Local batch size.
    pub fn batch(mut self, n: u32) -> Self {
        self.opts.batch = n;
        self
    }

    /// Local SGD steps per round.
    pub fn local_steps(mut self, n: u32) -> Self {
        self.opts.local_steps = n;
        self
    }

    /// Client learning rate.
    pub fn lr(mut self, lr: f32) -> Self {
        self.opts.lr = lr;
        self
    }

    /// Aggregation strategy by registered name (`fl::strategy::names()`
    /// lists them); resolved and validated at build.
    pub fn strategy(mut self, name: &str) -> Self {
        self.opts.strategy = name.to_string();
        self.strategy_override = None;
        self
    }

    /// Use this strategy instance directly (bypasses the registry; for
    /// one-off strategies that aren't worth registering).
    pub fn with_strategy(mut self, strategy: Box<dyn Strategy>) -> Self {
        self.opts.strategy = strategy.name().to_string();
        self.strategy_override = Some(strategy);
        self
    }

    /// Emulated-timeline slot count (`1` = the paper's strict sequential
    /// schedule; `>1` = the limited-parallel extension).
    pub fn max_parallel(mut self, n: usize) -> Self {
        self.opts.max_parallel = n;
        self
    }

    /// Scheduler by registered name (`sched::names()` lists them); built
    /// with the `max_parallel` slot count.  Default: name-less resolution
    /// (`sequential` / `limited-parallel` from `max_parallel`).
    pub fn scheduler(mut self, name: &str) -> Self {
        self.scheduler_name = Some(name.to_string());
        self
    }

    /// Real fit concurrency: pool threads with their own executors.
    /// Changes no emulated observable (DESIGN.md §8).
    pub fn workers(mut self, n: usize) -> Self {
        self.opts.workers = n;
        self
    }

    /// Mean-family reduction topology by name (`"serial"` or `"tree"`).
    /// Validated at build through [`FoldPlan::parse`]; the robust family
    /// (Krum, trimmed-mean) needs the full cohort and ignores the plan.
    /// See DESIGN.md §16.
    pub fn fold_plan(mut self, name: &str) -> Self {
        self.opts.fold_plan = name.to_string();
        self
    }

    /// Data partition scheme across clients.
    pub fn partition(mut self, scheme: PartitionScheme) -> Self {
        self.opts.partition = scheme;
        self
    }

    /// Per-round client selection policy.
    pub fn selection(mut self, selection: Selection) -> Self {
        self.opts.selection = selection;
        self
    }

    /// Run centralised evaluation every N rounds (0 = never).
    pub fn eval_every(mut self, n: u32) -> Self {
        self.opts.eval_every = n;
        self
    }

    /// Experiment seed (drives data, sampling, selection, dynamics).
    pub fn seed(mut self, seed: u64) -> Self {
        self.opts.seed = seed;
        self
    }

    /// How client hardware is chosen (survey sampler or explicit names).
    pub fn hardware(mut self, source: HardwareSource) -> Self {
        self.opts.hardware = source;
        self
    }

    /// Convenience for [`HardwareSource::Manual`]: preset/GPU names cycled
    /// over the client count.
    pub fn profiles(mut self, names: &[&str]) -> Self {
        self.opts.hardware =
            HardwareSource::Manual(names.iter().map(|s| s.to_string()).collect());
        self
    }

    /// Attach per-client network latency profiles.
    pub fn network(mut self, on: bool) -> Self {
        self.opts.network = on;
        self
    }

    /// The host machine the federation is emulated on.
    pub fn host(mut self, host: HardwareProfile) -> Self {
        self.opts.host = host;
        self
    }

    /// Directory holding the AOT artifacts (Real mode only).
    pub fn artifacts_dir(mut self, dir: PathBuf) -> Self {
        self.opts.artifacts_dir = dir;
        self
    }

    /// Real-time pacing scale (`None` = fast-forward).
    pub fn pacing(mut self, scale: Option<f64>) -> Self {
        self.opts.pacing = scale;
        self
    }

    /// Abort when a round ends with zero surviving clients (static
    /// federations only; see `ServerConfig`).
    pub fn fail_on_empty_round(mut self, on: bool) -> Self {
        self.opts.fail_on_empty_round = on;
        self
    }

    /// Workload descriptor for emulated timing/VRAM accounting.
    pub fn timing_workload(mut self, workload: TimingWorkload) -> Self {
        self.opts.timing_workload = workload;
        self
    }

    /// Federation-dynamics scenario (a static scenario compiles to
    /// nothing).
    pub fn scenario(mut self, scenario: Scenario) -> Self {
        self.scenario_name = None;
        self.opts.scenario = if scenario.is_static() { None } else { Some(scenario) };
        self
    }

    /// Scenario by preset name or file path (`Scenario::resolve` rules);
    /// resolved and validated at build.
    pub fn scenario_named(mut self, spec: &str) -> Self {
        self.scenario_name = Some(spec.to_string());
        self
    }

    /// Contention-aware communication simulation (DESIGN.md §12):
    /// per-round transfers share the server's finite ingress/egress
    /// capacity under max-min fair share, and updates travel through the
    /// configured compression codec.  Implies [`ExperimentBuilder::network`]
    /// — every client gets a sampled link.  Validated (capacities, codec
    /// name, top-k fraction) at build.
    pub fn netsim(mut self, cfg: NetSimConfig) -> Self {
        self.netsim_name = None;
        self.opts.netsim = Some(cfg);
        self
    }

    /// Netsim by preset name (`netsim::NETSIM_PRESETS` lists them);
    /// resolved and validated at build.
    pub fn netsim_named(mut self, preset: &str) -> Self {
        self.netsim_name = Some(preset.to_string());
        self
    }

    /// Adversarial participants (DESIGN.md §13): a seeded `fraction` of
    /// the fleet submits updates perturbed by the configured attack model
    /// at the server seam — after codec decode, immediately before the
    /// aggregation fold.  Membership is pure in `(seed, client)`, so the
    /// axis composes with populations, netsim and dynamics without
    /// breaking bit-identity.  Validated at build: model name, fraction,
    /// scale, and (strict mode) the strategy's Byzantine tolerance.
    pub fn attack(mut self, cfg: AttackConfig) -> Self {
        self.attack_name = None;
        self.opts.attack = Some(cfg);
        self
    }

    /// Attack by preset name (`fl::attack::ATTACK_PRESETS` lists them);
    /// resolved and validated at build.
    pub fn attack_named(mut self, preset: &str) -> Self {
        self.attack_name = Some(preset.to_string());
        self
    }

    /// Subscribe an observer to the run's typed event stream
    /// (`fl::events`).
    pub fn observer(mut self, observer: Box<dyn FlObserver>) -> Self {
        self.observers.push(observer);
        self
    }

    /// Log round progress through the crate logger while running.
    pub fn progress(mut self, on: bool) -> Self {
        self.progress = on;
        self
    }

    /// Collect run metrics (DESIGN.md §17): a [`MetricsObserver`] folds
    /// the event stream into the simulated-domain registry (bit-identical
    /// across worker counts and across crash/resume), and a
    /// [`PhaseRecorder`] times the round loop's phases on the host clock.
    /// The report's [`ExperimentReport::metrics`] carries the result, and
    /// host phase spans are merged into the Chrome trace under the
    /// `"phase"` category — so a metrics-enabled run's trace is *not*
    /// comparable across runs (the simulated rows still are).
    pub fn metrics(mut self) -> Self {
        self.metrics = true;
        self
    }

    /// Run a timing-only federation (`SimClient` fleet, `param_dim`-sized
    /// synthetic updates) — no artifacts or PJRT runtime needed.
    pub fn simulated(mut self, param_dim: usize) -> Self {
        self.mode = ExecutionMode::Simulated { param_dim };
        self
    }

    /// Record the run durably into `dir` (DESIGN.md §14): every event the
    /// round loop emits is appended to a CRC-framed log and the server's
    /// cross-round state is checkpointed each round, so a killed run can
    /// be resumed bit-identically and its outputs replayed offline.
    pub fn durable(mut self, dir: impl Into<PathBuf>) -> Self {
        self.opts.durable = Some(crate::durable::DurableOptions::new(dir));
        self
    }

    /// Durable recording with explicit options (checkpoint cadence,
    /// fault-injection crash point).
    pub fn durable_options(mut self, opts: crate::durable::DurableOptions) -> Self {
        self.opts.durable = Some(opts);
        self
    }

    /// Resume a previously recorded durable run from its directory
    /// instead of starting at round 0.  The builder's other axes must
    /// match the original run's (use `durable::read_manifest` /
    /// `options_from_manifest` to reconstruct them).
    pub fn resume(mut self, dir: impl Into<PathBuf>) -> Self {
        self.opts.durable = Some(crate::durable::DurableOptions::resume_dir(dir));
        self
    }

    /// Re-enable full cross-component validation on a builder created via
    /// [`ExperimentBuilder::from_options`].
    pub fn strict(mut self) -> Self {
        self.permissive = false;
        self
    }

    /// Resolve every component and validate cross-component constraints.
    ///
    /// Errors cover: unknown strategy/scheduler/scenario names (with the
    /// registered alternatives listed), zero-sized federations or rounds,
    /// selection fractions outside `[0, 1]`, strategies whose guarantee
    /// needs more per-round participants than the configuration can ever
    /// provide (e.g. Krum's Byzantine bound), and hardware that is not
    /// emulatable on the host.
    pub fn build(mut self) -> Result<Experiment, ConfigError> {
        let invalid = |key: &str, msg: String| ConfigError::InvalidValue {
            key: key.to_string(),
            msg,
        };
        self.opts.workers = self.opts.workers.max(1);
        // `population.size` supersedes `clients` (documented on
        // `PopulationOptions`).  The builder setters keep the pair in
        // sync, but both fields are `pub` on `LaunchOptions` — reconcile
        // here so a hand-built desync cannot size validation off one
        // number and the roster off the other (or worse, materialise
        // `clients` profiles for a `size`-client population).
        if let Some(p) = &self.opts.population {
            self.opts.clients = p.size;
        }
        // Sanity and cross-component checks are strict-mode only: the
        // permissive (legacy `launch()`) path must accept every
        // configuration the historical launcher accepted, degenerate ones
        // included, and fail where it would have failed (at run time).
        if !self.permissive {
            if self.opts.clients == 0 {
                return Err(invalid(
                    "clients",
                    "a federation needs at least one client".into(),
                ));
            }
            if self.opts.rounds == 0 {
                return Err(invalid("rounds", "a federation needs at least one round".into()));
            }
            if self.opts.batch == 0 || self.opts.local_steps == 0 {
                return Err(invalid(
                    "federation",
                    "batch and local_steps must be positive".into(),
                ));
            }
            if self.opts.samples_per_client == 0 {
                return Err(invalid(
                    "samples_per_client",
                    "clients need at least one training sample".into(),
                ));
            }
            if let Selection::Fraction(f) = self.opts.selection {
                if !(0.0..=1.0).contains(&f) {
                    return Err(invalid(
                        "selection.fraction",
                        format!("fraction {f} outside [0, 1]"),
                    ));
                }
            }
        }

        // Scenario: resolve a pending name, then validate staticness once.
        if let Some(spec) = &self.scenario_name {
            let sc = Scenario::resolve(spec)?;
            self.opts.scenario = if sc.is_static() { None } else { Some(sc) };
        }

        // Netsim: resolve a pending preset name, validate, and build the
        // runtime instance (codec through the registry, payload from the
        // timing workload's parameter bytes) — misconfigured capacities
        // or unknown codecs fail here, not mid-run.  The simulated pipe
        // needs per-client links on the other end, so netsim implies
        // `network`; this is an assembly requirement and applies on the
        // permissive path too.
        if let Some(name) = &self.netsim_name {
            self.opts.netsim =
                Some(NetSimConfig::preset(name).ok_or_else(|| {
                    invalid(
                        "netsim",
                        format!(
                            "unknown netsim preset '{name}' ({})",
                            NETSIM_PRESETS.join("|")
                        ),
                    )
                })?);
        }
        let netsim = match &self.opts.netsim {
            Some(cfg) => {
                self.opts.network = true;
                Some(NetSim::resolve(
                    cfg,
                    self.opts.timing_workload.cost().weight_bytes(),
                )?)
            }
            None => None,
        };

        // Attack: resolve a pending preset name, validate the config
        // (model registry, fraction, scale) and build the runtime
        // instance.  Like netsim, resolution is an assembly requirement
        // and applies on the permissive path too; the strategy-tolerance
        // cross-check below stays strict-mode only.
        if let Some(name) = &self.attack_name {
            self.opts.attack =
                Some(AttackConfig::preset(name).ok_or_else(|| {
                    invalid(
                        "attack",
                        format!(
                            "unknown attack preset '{name}' ({})",
                            ATTACK_PRESETS.join("|")
                        ),
                    )
                })?);
        }
        let attack = match &self.opts.attack {
            Some(cfg) => Some(Attack::resolve(cfg, self.opts.seed)?),
            None => None,
        };

        // Strategy: explicit instance, or registry resolution with
        // cohort-derived robustness knobs (`cohort_sized_strategy`).
        let strategy = match self.strategy_override {
            Some(s) => s,
            None => cohort_sized_strategy(&self.opts)?,
        };

        // Fold plan: the aggregation reduction topology is part of the
        // determinism contract, so an unknown name is a build error in
        // both modes (the permissive launcher never accepted one — the
        // field did not exist).
        let fold_plan = FoldPlan::parse(&self.opts.fold_plan).ok_or_else(|| {
            invalid(
                "fold_plan",
                format!(
                    "unknown fold plan '{}' (registered: {})",
                    self.opts.fold_plan,
                    FoldPlan::names().join("|")
                ),
            )
        })?;

        // Scheduler: explicit name through the registry, or the launcher's
        // historical max_parallel resolution.
        let scheduler = match &self.scheduler_name {
            Some(name) => sched::by_name(name, self.opts.max_parallel).ok_or_else(|| {
                invalid(
                    "scheduler",
                    format!(
                        "unknown scheduler '{name}' (registered: {})",
                        sched::names().join("|")
                    ),
                )
            })?,
            None => sched::for_parallelism(self.opts.max_parallel),
        };

        // Cross-component: can the configuration ever seat enough
        // participants for the strategy's guarantee?
        if !self.permissive {
            let participants = min_round_participants(self.opts.selection, self.opts.clients);
            let needed = strategy.min_clients();
            if participants < needed {
                return Err(invalid(
                    "strategy",
                    format!(
                        "strategy '{}' needs at least {needed} participants per round, \
                         but the configuration seats at most {participants} \
                         ({} clients, {:?} selection)",
                        strategy.name(),
                        self.opts.clients,
                        self.opts.selection
                    ),
                ));
            }
            // ...and an attacker fraction the defense provably cannot
            // absorb is a configuration error, not an experiment.
            // Strategies with no robustness guarantee (the mean family)
            // accept any fraction — attacking them is exactly what the
            // robustness lab measures.
            if let Some(a) = &self.opts.attack {
                let attackers = (a.fraction * participants as f64).ceil() as usize;
                if let Some(tolerated) = strategy.byzantine_tolerance(participants) {
                    if attackers > tolerated {
                        return Err(invalid(
                            "attack.fraction",
                            format!(
                                "{:.0}% attackers put {attackers} Byzantine updates in a \
                                 {participants}-participant round, but strategy '{}' only \
                                 tolerates {tolerated} there (Krum needs n > 2f + 2, \
                                 trimmed-mean n > 2·trim)",
                                a.fraction * 100.0,
                                strategy.name(),
                            ),
                        ));
                    }
                }
            }
        }

        // Hardware: resolved now so unknown presets / host-infeasible
        // profiles fail at build, not mid-run.  A population axis swaps
        // the per-client profile list for the descriptor layer; these
        // checks run in permissive mode too — they are assembly
        // requirements, not validation niceties.
        let (profiles, population) = match &self.opts.population {
            None => (resolve_hardware(&self.opts)?, None),
            Some(p) => {
                if p.size == 0 {
                    return Err(invalid(
                        "population.size",
                        "a population needs at least one client".into(),
                    ));
                }
                if !matches!(self.mode, ExecutionMode::Simulated { .. }) {
                    return Err(invalid(
                        "population",
                        "the population engine is timing-only: combine \
                         .population(n) with .simulated(param_dim) (real AOT \
                         training would need per-client data partitions at \
                         population scale)"
                            .into(),
                    ));
                }
                if p.size <= DENSE_POPULATION_MAX {
                    // Small populations resolve per-client hardware through
                    // the very same sampler stream as the materialised
                    // engine — explicit descriptors, bit-identical output
                    // (tests/properties.rs).
                    let profiles = resolve_hardware(&self.opts)?;
                    let pop = Population::from_profiles(
                        &profiles,
                        self.opts.samples_per_client,
                        self.opts.network,
                        self.opts.seed,
                    );
                    (profiles, Some(pop))
                } else {
                    if p.profile_draws == 0 {
                        return Err(invalid(
                            "population.profile_draws",
                            "a virtual population needs at least one profile draw".into(),
                        ));
                    }
                    let table = resolve_profile_table(&self.opts, p.profile_draws)?;
                    let pop = match &self.opts.hardware {
                        HardwareSource::Sampler(_) => Population::virtual_survey(
                            self.opts.seed,
                            p.size,
                            table,
                            self.opts.samples_per_client,
                            self.opts.network,
                        ),
                        HardwareSource::Manual(_) => Population::virtual_cycle(
                            self.opts.seed,
                            p.size,
                            table,
                            self.opts.samples_per_client,
                            self.opts.network,
                        ),
                    };
                    // The report's profile list is the deduplicated table
                    // (descriptor indices refer to it), not a per-client
                    // materialisation.
                    (pop.profile_table().profiles().to_vec(), Some(pop))
                }
            }
        };

        Ok(Experiment {
            opts: self.opts,
            strategy,
            fold_plan,
            scheduler,
            profiles,
            population,
            netsim,
            attack,
            observers: self.observers,
            mode: self.mode,
            progress: self.progress,
            metrics: self.metrics,
        })
    }
}

/// The smallest participant count a selection policy can seat per round.
fn min_round_participants(selection: Selection, clients: usize) -> usize {
    match selection {
        Selection::All => clients,
        Selection::Fraction(f) => {
            ((clients as f64 * f).round() as usize).clamp(1, clients)
        }
        Selection::Count(k) => k.clamp(1, clients),
    }
}

/// Registry resolution with cohort-derived robustness knobs.
///
/// The registry's factories are cohort-blind, so resolving the robust
/// strategies *by name* historically froze them at `Krum::new(1, 3)` /
/// `TrimmedMean::new(1)` — silently under-defending any federation larger
/// than a handful of clients.  Instead, size them for the per-round
/// participant count `k` the configuration seats: the largest `f` Krum's
/// `k > 2f + 2` bound admits (averaging the `k - 2f - 2` guaranteed-honest
/// top scorers, multi-Krum style) and a quarter-of-the-cohort tail trim
/// for trimmed-mean.  Both floor at their historical knobs (`f = 1`,
/// `trim = 1`), so tiny federations behave exactly as before — and
/// cohorts too small even for those still fail loudly at the strict-mode
/// `min_clients` cross-check in `build()`.
fn cohort_sized_strategy(opts: &LaunchOptions) -> Result<Box<dyn Strategy>, ConfigError> {
    let k = min_round_participants(opts.selection, opts.clients);
    match opts.strategy.as_str() {
        "krum" => {
            let f = (k.saturating_sub(3) / 2).max(1);
            let m = k.saturating_sub(2 * f + 2).max(1);
            Ok(Box::new(Krum::new(f, m)))
        }
        "trimmed-mean" => Ok(Box::new(TrimmedMean::new((k.saturating_sub(1) / 4).max(1)))),
        _ => opts.strategy_box(),
    }
}

/// A fully resolved, validated experiment — every component is already
/// constructed; [`Experiment::run`] cannot fail on configuration.
pub struct Experiment {
    opts: LaunchOptions,
    strategy: Box<dyn Strategy>,
    /// Resolved aggregation reduction topology (DESIGN.md §16).
    fold_plan: FoldPlan,
    scheduler: Box<dyn Scheduler>,
    profiles: Vec<HardwareProfile>,
    /// Descriptor-backed roster (`Some` when the population axis is set).
    population: Option<Population>,
    /// Resolved communication simulator (`Some` when the netsim axis is
    /// set; DESIGN.md §12).
    netsim: Option<NetSim>,
    /// Resolved adversarial participants (`Some` when the attack axis is
    /// set; DESIGN.md §13).
    attack: Option<Attack>,
    observers: Vec<Box<dyn FlObserver>>,
    mode: ExecutionMode,
    progress: bool,
    metrics: bool,
}

impl Experiment {
    /// Start building an experiment (strict validation).
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder::default()
    }

    /// Build directly from [`LaunchOptions`] with the legacy `launch()`
    /// semantics (permissive validation) — the compatibility shim.
    pub fn from_options(opts: LaunchOptions) -> Result<Experiment, ConfigError> {
        ExperimentBuilder::from_options(opts).build()
    }

    /// The resolved launch options.
    pub fn options(&self) -> &LaunchOptions {
        &self.opts
    }

    /// The federation's resolved hardware: one profile per client for
    /// materialised fleets and below-threshold populations; for *virtual*
    /// populations, the deduplicated profile table's entries (descriptor
    /// indices refer to it).
    pub fn profiles(&self) -> &[HardwareProfile] {
        &self.profiles
    }

    /// The descriptor-backed roster, when the population axis is set.
    pub fn population(&self) -> Option<&Population> {
        self.population.as_ref()
    }

    /// Assemble data, clients, server and clock, run the federation, and
    /// return the typed report.
    ///
    /// This is byte-for-byte the historical `launch()` assembly: same
    /// seeds, same RNG stream order, same server wiring — the bit-identity
    /// contract between the two paths is asserted in
    /// `tests/experiment_api.rs`.
    pub fn run(self) -> Result<ExperimentReport, FlError> {
        let Experiment {
            opts,
            strategy,
            fold_plan,
            scheduler,
            profiles,
            population,
            netsim,
            attack,
            mut observers,
            mode,
            progress,
            metrics,
        } = self;
        if progress {
            observers.push(Box::new(ProgressLogger::default()));
        }
        // Metrics ride the observer list so a durable resume's replayed
        // event prefix reaches them exactly like a live event would —
        // the simulated registry stays bit-identical across crash/resume.
        let hub = if metrics {
            let hub = MetricsHub::new();
            observers.push(Box::new(MetricsObserver::new(hub.clone())));
            Some(hub)
        } else {
            None
        };
        let strategy_name = strategy.name().to_string();
        let scenario_name = opts
            .scenario
            .as_ref()
            .map(|sc| sc.name.clone())
            .unwrap_or_else(|| "stable".to_string());

        let workload = opts.timing_workload.cost();
        let server_cfg = ServerConfig {
            rounds: opts.rounds,
            selection: opts.selection,
            fit: FitConfig {
                lr: opts.lr,
                local_steps: opts.local_steps,
                batch: opts.batch,
                ..Default::default()
            },
            eval_every: opts.eval_every,
            seed: opts.seed,
            fail_on_empty_round: opts.fail_on_empty_round,
        };

        let mut server = if let Some(pop) = population {
            // Descriptor-backed roster: clients are instantiated per
            // round by the factory; nothing O(population) is built here
            // (build() limited itself to the profile table).  Simulated
            // mode only — enforced at build.
            ServerApp::with_population(
                server_cfg,
                opts.host.clone(),
                strategy,
                scheduler,
                pop,
                Box::new(SimClientFactory::new(workload)),
            )
        } else {
            let mut net_rng = Pcg::new(opts.seed, NET_STREAM);
            let (clients, eval): (Vec<Box<dyn ClientApp>>, Option<Dataset>) = match mode {
                ExecutionMode::Real => {
                    // Data: one synthetic corpus, partitioned across
                    // clients + held-out eval.
                    let total = opts.clients * opts.samples_per_client;
                    let train = generate(
                        &SyntheticConfig { seed: opts.seed, ..Default::default() },
                        total,
                    );
                    let eval = generate(
                        &SyntheticConfig { seed: opts.seed ^ 0xE7A1, ..Default::default() },
                        opts.eval_samples,
                    );
                    let parts = partition(&train, opts.clients, opts.partition, opts.seed);
                    let clients = profiles
                        .iter()
                        .enumerate()
                        .map(|(i, profile)| {
                            let subset: Dataset = train.subset(&parts[i]);
                            let mut c = TrainClient::new(
                                i as u32,
                                profile.clone(),
                                subset,
                                workload.clone(),
                                opts.seed ^ (i as u64) << 8,
                            );
                            if opts.network {
                                c = c.with_network(sample_network(&mut net_rng));
                            }
                            Box::new(c) as Box<dyn ClientApp>
                        })
                        .collect();
                    (clients, Some(eval))
                }
                ExecutionMode::Simulated { .. } => {
                    let clients = profiles
                        .iter()
                        .enumerate()
                        .map(|(i, profile)| {
                            let mut c = SimClient::new(
                                i as u32,
                                profile.clone(),
                                opts.samples_per_client,
                                workload.clone(),
                            );
                            if opts.network {
                                c.network = Some(sample_network(&mut net_rng));
                            }
                            Box::new(c) as Box<dyn ClientApp>
                        })
                        .collect();
                    (clients, None)
                }
            };
            let mut server =
                ServerApp::new(server_cfg, opts.host.clone(), strategy, scheduler, clients);
            if let Some(eval) = eval {
                server = server.with_eval_data(eval);
            }
            server
        };
        if let Some(sc) = &opts.scenario {
            server = server.with_scenario(sc);
        }
        if let Some(ns) = netsim {
            server = server.with_netsim(ns);
        }
        if let Some(atk) = attack {
            server = server.with_attack(atk);
        }
        server = server.with_fold_plan(fold_plan);
        for observer in observers {
            server = server.with_observer(observer);
        }
        if let Some(hub) = &hub {
            server = server.with_phase_recorder(PhaseRecorder::new(hub.clone()));
        }
        if opts.workers > 1 {
            // Each pool worker builds (and caches) its own executor over
            // the same artifact directory; real fits then overlap while
            // the emulated timeline stays exactly as scheduled.  Simulated
            // fleets need no executors at all.
            let factory = match mode {
                ExecutionMode::Real => {
                    let dir = opts.artifacts_dir.clone();
                    Some(Arc::new(move || ModelExecutor::new(&dir))
                        as crate::sched::ExecutorFactory)
                }
                ExecutionMode::Simulated { .. } => None,
            };
            server = server.with_round_engine(opts.workers, factory);
        }
        if let Some(dopt) = &opts.durable {
            let derr =
                |e: std::io::Error| FlError::Durable(format!("{}: {e}", dopt.dir.display()));
            let durability = if dopt.resume {
                RunDurability::resume(&dopt.dir).map_err(derr)?
            } else {
                let meta = LogMeta {
                    strategy: strategy_name.clone(),
                    scenario: scenario_name.clone(),
                    seed: opts.seed,
                    rounds: opts.rounds,
                    clients: opts
                        .population
                        .as_ref()
                        .map(|p| p.size)
                        .unwrap_or(opts.clients),
                };
                RunDurability::fresh(&dopt.dir, dopt.every_k, &meta).map_err(derr)?
            };
            server = server.with_durable(durability.with_crash(dopt.crash));
        }

        let mut clock = match opts.pacing {
            Some(scale) => VirtualClock::new(ClockMode::Realtime { scale }),
            None => VirtualClock::fast_forward(),
        };
        let (global, history) = match mode {
            ExecutionMode::Real => {
                let mut executor = ModelExecutor::new(&opts.artifacts_dir)
                    .map_err(|e| FlError::Strategy(format!("runtime: {e}")))?;
                server.run(&mut executor, &mut clock)?
            }
            ExecutionMode::Simulated { param_dim } => {
                server.run_from(ParamVector::zeros(param_dim), None, &mut clock)?
            }
        };
        let mut trace = std::mem::take(&mut server.trace);
        let metrics = hub.map(|hub| {
            hub.with(|m| {
                m.host
                    .set("peak_rss_bytes", crate::util::benchkit::peak_rss_bytes() as f64)
            });
            let snapshot = hub.snapshot();
            // Host phase spans join the Chrome trace on their own pseudo
            // row (tid u32::MAX) under the "phase" category.  Host-clock
            // timestamps, so a metrics-enabled trace varies run to run —
            // the simulated fit/comm/attack rows do not.
            for span in &snapshot.phase_spans {
                trace.add_cat(
                    u32::MAX,
                    format!("phase:{}", span.phase.name()),
                    "phase",
                    span.start_s,
                    span.end_s,
                );
            }
            snapshot
        });
        Ok(ExperimentReport {
            global,
            history,
            profiles,
            trace,
            metrics,
            strategy: strategy_name,
            scenario: scenario_name,
            seed: opts.seed,
        })
    }
}

/// Everything a finished experiment produced.
pub struct ExperimentReport {
    /// The final global model.
    pub global: ParamVector,
    /// Round-by-round training history.
    pub history: History,
    /// The federation's hardware: index-aligned with client ids for
    /// materialised fleets and below-threshold populations; for virtual
    /// populations, the deduplicated profile table's entries (each
    /// client's descriptor indexes into it — see DESIGN.md §11).
    pub profiles: Vec<HardwareProfile>,
    /// Per-client fit spans on the emulated timeline (Chrome-trace ready).
    pub trace: Trace,
    /// Run metrics (`Some` iff [`ExperimentBuilder::metrics`] was set):
    /// the simulated-domain registry (bit-identical, DESIGN.md §17), the
    /// host-domain registry and the host phase spans.
    pub metrics: Option<RunMetrics>,
    /// Resolved strategy name.
    pub strategy: String,
    /// Scenario name (`"stable"` for static federations).
    pub scenario: String,
    /// The experiment seed.
    pub seed: u64,
}

/// `NaN`/infinite metrics export as JSON `null` (JSON has no non-finite
/// numbers; an all-failed round's loss is NaN by design).  Shared with
/// the campaign JSONL rows so the two export paths cannot diverge.
pub(crate) fn finite_num(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

impl ExperimentReport {
    /// Example-weighted training loss of the last round.
    pub fn final_train_loss(&self) -> Option<f32> {
        self.history.final_train_loss()
    }

    /// Most recent centralised (loss, accuracy), if evaluation ever ran.
    pub fn last_eval(&self) -> Option<(f32, f32)> {
        self.history.last_eval()
    }

    /// Total emulated federation seconds.
    pub fn total_emu_s(&self) -> f64 {
        self.history.total_emu_seconds()
    }

    /// Total client failures across all rounds.
    pub fn failures(&self) -> usize {
        self.history.total_failures()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "[{} / {} / seed {}] {}",
            self.strategy,
            self.scenario,
            self.seed,
            self.history.summary()
        )
    }

    /// Flat summary row of this experiment (strategy/scenario/seed plus
    /// headline metrics) for ad-hoc JSONL logging.  Campaign cells export
    /// their own richer rows ([`super::campaign::CellOutcome::to_json`])
    /// that add sweep coordinates and error status.
    pub fn to_json(&self) -> Json {
        let (eval_loss, eval_accuracy) = match self.last_eval() {
            Some((l, a)) => (finite_num(l as f64), finite_num(a as f64)),
            None => (Json::Null, Json::Null),
        };
        Json::obj(vec![
            ("strategy", Json::str(self.strategy.clone())),
            ("scenario", Json::str(self.scenario.clone())),
            // 64-bit seeds don't survive the f64 round-trip JSON numbers
            // imply; export exactly, as a string.
            ("seed", Json::str(self.seed.to_string())),
            ("rounds", Json::num(self.history.rounds.len() as f64)),
            (
                "final_train_loss",
                self.final_train_loss()
                    .map(|x| finite_num(x as f64))
                    .unwrap_or(Json::Null),
            ),
            ("eval_loss", eval_loss),
            ("eval_accuracy", eval_accuracy),
            ("total_emu_s", finite_num(self.total_emu_s())),
            ("failures", Json::num(self.failures() as f64)),
        ])
    }

    /// The `metrics.json` document (the simulated-domain registry plus
    /// derived rates) — `None` unless the run was built with
    /// [`ExperimentBuilder::metrics`].  This is the byte-identity surface
    /// `bouquetfl stats` reproduces from a durable run's event log.
    pub fn metrics_json(&self) -> Option<Json> {
        self.metrics.as_ref().map(|m| m.sim_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_strategy_is_rejected_with_the_registry_list() {
        let err = Experiment::builder()
            .profiles(&["gtx-1060"])
            .strategy("nope")
            .build()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("nope"), "{msg}");
        assert!(msg.contains("fedavg") && msg.contains("krum"), "{msg}");
    }

    #[test]
    fn unknown_scheduler_is_rejected_with_the_registry_list() {
        let err = Experiment::builder()
            .profiles(&["gtx-1060"])
            .scheduler("wat")
            .build()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("wat") && msg.contains("sequential"), "{msg}");
    }

    #[test]
    fn krum_below_its_byzantine_bound_is_rejected() {
        // Krum(f=1) needs > 2f+2 = 4 participants; 3 clients cannot seat it.
        let err = Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(3)
            .strategy("krum")
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("krum"), "{err}");
        // ...but 5 clients can.
        assert!(Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(5)
            .strategy("krum")
            .build()
            .is_ok());
        // The permissive (legacy launch) path keeps the old leniency.
        let opts = LaunchOptions {
            clients: 3,
            strategy: "krum".into(),
            hardware: HardwareSource::Manual(vec!["gtx-1060".into()]),
            ..Default::default()
        };
        assert!(Experiment::from_options(opts).is_ok());
    }

    #[test]
    fn fraction_selection_cuts_participants_for_the_bound() {
        // 10 clients at fraction 0.2 -> 2 per round: trimmed-mean(1)
        // needs 3.
        let err = Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(10)
            .selection(Selection::Fraction(0.2))
            .strategy("trimmed-mean")
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("trimmed-mean"), "{err}");
    }

    #[test]
    fn robust_defaults_derive_from_the_cohort() {
        // 20 clients, everyone selected: krum must size f for k = 20
        // (f = 8 -> min_clients = 19), not the historical Krum::new(1, 3).
        let exp = Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(20)
            .strategy("krum")
            .build()
            .unwrap();
        assert_eq!(exp.strategy.min_clients(), 19, "krum f derives from the cohort");
        assert_eq!(exp.strategy.byzantine_tolerance(20), Some(8));
        // Selection cuts the cohort the derivation sees: 20 clients at
        // fraction 0.5 seat k = 10 -> f = 3.
        let exp = Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(20)
            .selection(Selection::Fraction(0.5))
            .strategy("krum")
            .build()
            .unwrap();
        assert_eq!(exp.strategy.min_clients(), 9);
        // trimmed-mean trims a quarter of the cohort per tail: trim = 4.
        let exp = Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(20)
            .strategy("trimmed-mean")
            .build()
            .unwrap();
        assert_eq!(exp.strategy.min_clients(), 9);
        // Small federations keep the historical floor (f = 1, trim = 1).
        let exp = Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(5)
            .strategy("krum")
            .build()
            .unwrap();
        assert_eq!(exp.strategy.min_clients(), 5);
        // An explicit instance is never resized behind the caller's back.
        let exp = Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(20)
            .with_strategy(Box::new(Krum::new(1, 3)))
            .build()
            .unwrap();
        assert_eq!(exp.strategy.min_clients(), 5);
    }

    #[test]
    fn attack_axis_resolves_and_validates_at_build() {
        let exp = Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(10)
            .attack_named("scaled")
            .simulated(32)
            .build()
            .unwrap();
        let a = exp.options().attack.as_ref().expect("preset resolved");
        assert_eq!(a.model, "scaled");
        assert_eq!(a.scale, 10.0);
        assert!(exp.attack.is_some(), "runtime instance built at build()");
        // Unknown presets and invalid knobs fail at build.
        assert!(Experiment::builder()
            .profiles(&["gtx-1060"])
            .attack_named("nope")
            .build()
            .is_err());
        assert!(Experiment::builder()
            .profiles(&["gtx-1060"])
            .attack(AttackConfig { fraction: 1.5, ..Default::default() })
            .build()
            .is_err());
    }

    #[test]
    fn attacker_fraction_above_the_strategy_tolerance_is_rejected() {
        // 10 participants: cohort-derived krum tolerates f = 3, but 40%
        // attackers put 4 Byzantine updates in the round.
        let err = Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(10)
            .strategy("krum")
            .attack(AttackConfig { fraction: 0.4, ..Default::default() })
            .build()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("tolerates"), "{msg}");
        // 20% (= 2 of 10) sits inside the bound.
        assert!(Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(10)
            .strategy("krum")
            .attack_named("sign-flip")
            .build()
            .is_ok());
        // FedAvg promises nothing, so any fraction builds — that run is
        // the robustness lab's divergence baseline.
        assert!(Experiment::builder()
            .profiles(&["gtx-1060"])
            .clients(10)
            .attack(AttackConfig { fraction: 0.9, ..Default::default() })
            .build()
            .is_ok());
        // The permissive (legacy launch) path skips the tolerance check.
        let opts = LaunchOptions {
            clients: 10,
            strategy: "krum".into(),
            hardware: HardwareSource::Manual(vec!["gtx-1060".into()]),
            attack: Some(AttackConfig { fraction: 0.4, ..Default::default() }),
            ..Default::default()
        };
        assert!(Experiment::from_options(opts).is_ok());
    }

    #[test]
    fn zero_sized_federations_are_rejected() {
        assert!(Experiment::builder().clients(0).build().is_err());
        assert!(Experiment::builder().profiles(&["gtx-1060"]).rounds(0).build().is_err());
        assert!(Experiment::builder()
            .profiles(&["gtx-1060"])
            .samples_per_client(0)
            .build()
            .is_err());
        assert!(Experiment::builder()
            .profiles(&["gtx-1060"])
            .selection(Selection::Fraction(1.5))
            .build()
            .is_err());
    }

    #[test]
    fn builder_resolves_scenarios_and_hardware_at_build() {
        let exp = Experiment::builder()
            .profiles(&["gtx-1060", "rtx-3060"])
            .clients(4)
            .scenario_named("high-churn")
            .build()
            .unwrap();
        assert_eq!(exp.profiles().len(), 4);
        assert_eq!(exp.options().scenario.as_ref().unwrap().name, "high-churn");
        // The stable preset compiles to no dynamics at all.
        let exp = Experiment::builder()
            .profiles(&["gtx-1060"])
            .scenario_named("stable")
            .build()
            .unwrap();
        assert!(exp.options().scenario.is_none());
        // Unknown presets and infeasible hardware fail at build.
        assert!(Experiment::builder()
            .profiles(&["gtx-1060"])
            .scenario_named("nope")
            .build()
            .is_err());
        assert!(Experiment::builder().profiles(&["rtx-4090"]).build().is_err());
    }

    #[test]
    fn netsim_axis_resolves_and_validates_at_build() {
        let exp = Experiment::builder()
            .profiles(&["gtx-1060"])
            .netsim_named("congested-cell")
            .simulated(32)
            .build()
            .unwrap();
        assert!(exp.options().netsim.is_some());
        assert!(exp.options().network, "netsim implies per-client links");
        // Unknown presets, codecs and degenerate capacities fail at build.
        assert!(Experiment::builder()
            .profiles(&["gtx-1060"])
            .netsim_named("nope")
            .build()
            .is_err());
        assert!(Experiment::builder()
            .profiles(&["gtx-1060"])
            .netsim(NetSimConfig { codec: "zstd".into(), ..Default::default() })
            .build()
            .is_err());
        assert!(Experiment::builder()
            .profiles(&["gtx-1060"])
            .netsim(NetSimConfig { ingress_mbps: -1.0, ..Default::default() })
            .build()
            .is_err());
    }

    #[test]
    fn population_axis_requires_simulated_mode() {
        // Real mode (the default) cannot run a descriptor population.
        let err = Experiment::builder()
            .profiles(&["gtx-1060"])
            .population(100)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("simulated"), "{err}");
        // With simulated mode it builds; small sizes keep per-client
        // profiles, large ones carry only the deduplicated table.
        let exp = Experiment::builder()
            .population(100)
            .simulated(32)
            .build()
            .unwrap();
        assert!(exp.population().is_some());
        assert_eq!(exp.profiles().len(), 100);
        let exp = Experiment::builder()
            .population(DENSE_POPULATION_MAX + 1)
            .simulated(32)
            .build()
            .unwrap();
        assert_eq!(exp.population().unwrap().len(), DENSE_POPULATION_MAX + 1);
        assert!(
            exp.profiles().len() <= 256,
            "virtual population must not materialise per-client profiles \
             ({} entries)",
            exp.profiles().len()
        );
        // Degenerate axes fail at build.
        assert!(Experiment::builder().population(0).simulated(8).build().is_err());
        assert!(Experiment::builder()
            .population_options(PopulationOptions {
                size: DENSE_POPULATION_MAX + 1,
                profile_draws: 0
            })
            .simulated(8)
            .build()
            .is_err());
    }

    #[test]
    fn clients_and_population_axes_stay_in_sync() {
        let exp = Experiment::builder()
            .population(50)
            .clients(20)
            .simulated(8)
            .build()
            .unwrap();
        assert_eq!(exp.population().unwrap().len(), 20);
        assert_eq!(exp.options().clients, 20);
    }

    #[test]
    fn min_round_participants_matches_selection_semantics() {
        assert_eq!(min_round_participants(Selection::All, 8), 8);
        assert_eq!(min_round_participants(Selection::Fraction(0.5), 8), 4);
        assert_eq!(min_round_participants(Selection::Fraction(0.01), 8), 1);
        assert_eq!(min_round_participants(Selection::Count(3), 8), 3);
        assert_eq!(min_round_participants(Selection::Count(99), 8), 8);
    }
}
