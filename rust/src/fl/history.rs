//! Round-by-round training history + JSON export.

use crate::util::json::Json;

/// Reason prefix marking a mid-round availability dropout.  The server's
/// dynamics gate writes it and `analysis::report::dynamics_table`
/// classifies by it — shared here so the two cannot drift apart.
pub const DROPOUT_REASON_PREFIX: &str = "dropout:";
/// Reason prefix marking a client that missed the round deadline.
pub const DEADLINE_REASON_PREFIX: &str = "deadline:";

/// Record of one client's failure in a round.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureRecord {
    pub client: u32,
    pub reason: String,
}

/// One round's record.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    pub round: u32,
    pub selected: Vec<u32>,
    pub failures: Vec<FailureRecord>,
    /// Example-weighted mean of client training losses.
    pub train_loss: f32,
    /// Centralised evaluation (if run this round).
    pub eval_loss: Option<f32>,
    pub eval_accuracy: Option<f32>,
    /// Emulated wall-clock of the round (scheduler-dependent).
    pub emu_round_s: f64,
    /// Host wall-clock spent on the real execution.
    pub host_round_s: f64,
}

/// Federation history.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct History {
    pub rounds: Vec<RoundRecord>,
}

impl History {
    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn final_train_loss(&self) -> Option<f32> {
        self.rounds.last().map(|r| r.train_loss)
    }

    pub fn last_eval(&self) -> Option<(f32, f32)> {
        self.rounds
            .iter()
            .rev()
            .find_map(|r| r.eval_loss.zip(r.eval_accuracy))
    }

    pub fn total_emu_seconds(&self) -> f64 {
        self.rounds.iter().map(|r| r.emu_round_s).sum()
    }

    pub fn total_failures(&self) -> usize {
        self.rounds.iter().map(|r| r.failures.len()).sum()
    }

    /// Export as JSON (for plotting — see EXPERIMENTS.md §Evidence).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rounds
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("round", Json::num(r.round as f64)),
                        (
                            "selected",
                            Json::Arr(
                                r.selected.iter().map(|&c| Json::num(c as f64)).collect(),
                            ),
                        ),
                        (
                            "failures",
                            Json::Arr(
                                r.failures
                                    .iter()
                                    .map(|f| {
                                        Json::obj(vec![
                                            ("client", Json::num(f.client as f64)),
                                            ("reason", Json::str(f.reason.clone())),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        ("train_loss", Json::num(r.train_loss as f64)),
                        (
                            "eval_loss",
                            r.eval_loss.map(|x| Json::num(x as f64)).unwrap_or(Json::Null),
                        ),
                        (
                            "eval_accuracy",
                            r.eval_accuracy
                                .map(|x| Json::num(x as f64))
                                .unwrap_or(Json::Null),
                        ),
                        ("emu_round_s", Json::num(r.emu_round_s)),
                        ("host_round_s", Json::num(r.host_round_s)),
                    ])
                })
                .collect(),
        )
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let n = self.rounds.len();
        let first = self.rounds.first().map(|r| r.train_loss).unwrap_or(f32::NAN);
        let last = self.final_train_loss().unwrap_or(f32::NAN);
        let eval = self
            .last_eval()
            .map(|(l, a)| format!(", eval loss {l:.3} acc {:.1}%", a * 100.0))
            .unwrap_or_default();
        format!(
            "{n} rounds: train loss {first:.3} -> {last:.3}{eval}, \
             {} failures, {:.1}s emulated",
            self.total_failures(),
            self.total_emu_seconds()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: u32, loss: f32) -> RoundRecord {
        RoundRecord {
            round,
            selected: vec![0, 1],
            failures: vec![],
            train_loss: loss,
            eval_loss: None,
            eval_accuracy: None,
            emu_round_s: 2.0,
            host_round_s: 0.1,
        }
    }

    #[test]
    fn accumulates_and_summarises() {
        let mut h = History::default();
        h.push(record(0, 2.0));
        h.push(RoundRecord {
            eval_loss: Some(1.0),
            eval_accuracy: Some(0.5),
            failures: vec![FailureRecord { client: 3, reason: "OOM".into() }],
            ..record(1, 1.5)
        });
        assert_eq!(h.final_train_loss(), Some(1.5));
        assert_eq!(h.last_eval(), Some((1.0, 0.5)));
        assert_eq!(h.total_failures(), 1);
        assert!((h.total_emu_seconds() - 4.0).abs() < 1e-12);
        assert!(h.summary().contains("2 rounds"));
    }

    #[test]
    fn json_roundtrips() {
        let mut h = History::default();
        h.push(record(0, 2.0));
        let j = h.to_json();
        let parsed = Json::parse(&j.pretty()).unwrap();
        assert_eq!(
            parsed.as_arr().unwrap()[0].get("train_loss").unwrap().as_f64().unwrap(),
            2.0
        );
    }
}
