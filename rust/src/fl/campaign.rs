//! Declarative multi-run campaigns: sweep seeds × scenarios × strategies
//! from one API call, with deterministic per-cell seeding and JSONL
//! result export.
//!
//! The paper's users "configure the federation according to their
//! preference" — a [`Campaign`] makes the resulting sweep a first-class
//! object instead of a shell loop:
//!
//! ```no_run
//! use bouquetfl::fl::campaign::Campaign;
//! use bouquetfl::fl::launcher::LaunchOptions;
//! use bouquetfl::fl::Scenario;
//!
//! let report = Campaign::new("robustness", LaunchOptions::default())
//!     .seeds(&[1, 2, 3])
//!     .strategies(&["fedavg", "trimmed-mean"])
//!     .scenarios(&[
//!         Scenario::preset("stable").unwrap(),
//!         Scenario::preset("high-churn").unwrap(),
//!     ])
//!     .run();
//! println!("{}", report.to_jsonl());
//! ```
//!
//! Every cell's experiment seed is derived from its **coordinates**
//! (replicate seed, strategy name, scenario name) — never from its
//! position in the sweep — so adding a strategy to the list, or permuting
//! it, changes no other cell's result ([`cell_seed`]).
#![deny(missing_docs)]

use std::fs::File;
use std::io::{Seek as _, SeekFrom, Write as _};
use std::path::Path;

use crate::util::json::Json;

use super::attack::{AttackConfig, ATTACK_PRESETS};
use super::experiment::{finite_num, ExecutionMode, ExperimentBuilder};
use super::launcher::LaunchOptions;
use super::scenario::Scenario;

/// SplitMix64 — the standard 64-bit seed mixer (Steele et al., 2014).
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// FNV-1a over a string (for hashing component names into the seed mix).
fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    h
}

/// The experiment seed of the campaign cell at coordinates
/// `(seed, strategy, scenario)`.  Deterministic, order-independent, and
/// axis-separated (swapping the strategy and scenario names yields a
/// different cell seed).
pub fn cell_seed(seed: u64, strategy: &str, scenario: &str) -> u64 {
    splitmix64(seed ^ splitmix64(fnv1a64(strategy)) ^ splitmix64(fnv1a64(scenario)).rotate_left(17))
}

/// The experiment seed of a cell with an attack coordinate.  Honest cells
/// (`None`) keep exactly the historical three-coordinate [`cell_seed`], so
/// adding an attack axis to an existing sweep changes no honest cell's
/// result; attacked cells mix the preset name in as a fourth axis.
pub fn attacked_cell_seed(
    seed: u64,
    strategy: &str,
    scenario: &str,
    attack: Option<&str>,
) -> u64 {
    let base = cell_seed(seed, strategy, scenario);
    match attack {
        None => base,
        Some(a) => splitmix64(base ^ splitmix64(fnv1a64(a)).rotate_left(29)),
    }
}

/// File holding one JSONL row per finished cell inside a durable
/// campaign directory ([`Campaign::run_durable`]).
pub const CELLS_FILE: &str = "cells.jsonl";

/// Cursor file recording the grid fingerprint and the finished-cell
/// count inside a durable campaign directory.
pub const CURSOR_FILE: &str = "cursor";

const CURSOR_HEADER: &str = "bouquetfl-campaign v1";

/// One cell of the sweep grid.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignCell {
    /// The replicate seed (the `seeds` axis value).
    pub seed: u64,
    /// Strategy name (the `strategies` axis value).
    pub strategy: String,
    /// Scenario name (the `scenarios` axis value).
    pub scenario: String,
    /// Attack preset name (the `attacks` axis value; `None` = the honest
    /// cell, which inherits whatever the base options say).
    pub attack: Option<String>,
    /// The derived experiment seed ([`attacked_cell_seed`]).
    pub cell_seed: u64,
}

/// A declarative sweep over seeds × scenarios × strategies.
pub struct Campaign {
    name: String,
    base: LaunchOptions,
    seeds: Vec<u64>,
    strategies: Vec<String>,
    scenarios: Vec<Scenario>,
    attacks: Vec<Option<String>>,
    mode: ExecutionMode,
}

impl Campaign {
    /// A campaign named `name` whose every cell starts from `base`
    /// (axes default to the base's seed/strategy/scenario).
    pub fn new(name: &str, base: LaunchOptions) -> Self {
        let seeds = vec![base.seed];
        let strategies = vec![base.strategy.clone()];
        let scenarios = vec![base.scenario.clone().unwrap_or_default()];
        Campaign {
            name: name.to_string(),
            base,
            seeds,
            strategies,
            scenarios,
            attacks: vec![None],
            mode: ExecutionMode::Real,
        }
    }

    /// Replicate seeds to sweep.
    pub fn seeds(mut self, seeds: &[u64]) -> Self {
        self.seeds = seeds.to_vec();
        self
    }

    /// Strategy names to sweep (resolved through the `fl::strategy`
    /// registry per cell).
    pub fn strategies(mut self, names: &[&str]) -> Self {
        self.strategies = names.iter().map(|s| s.to_string()).collect();
        self
    }

    /// Scenarios to sweep (use `Scenario::preset` / `Scenario::resolve`
    /// to obtain them by name).
    pub fn scenarios(mut self, scenarios: &[Scenario]) -> Self {
        self.scenarios = scenarios.to_vec();
        self
    }

    /// Attack presets to sweep (`"none"` = the honest baseline cell;
    /// other names resolve through `fl::attack::ATTACK_PRESETS` per cell).
    /// This axis is what turns a strategy sweep into an attack-vs-defense
    /// matrix (EXPERIMENTS.md §Attack-vs-defense).
    pub fn attacks(mut self, names: &[&str]) -> Self {
        self.attacks = names
            .iter()
            .map(|s| (*s != "none").then(|| s.to_string()))
            .collect();
        self
    }

    /// Run every cell as a timing-only federation (no artifacts needed;
    /// see `ExperimentBuilder::simulated`).
    pub fn simulated(mut self, param_dim: usize) -> Self {
        self.mode = ExecutionMode::Simulated { param_dim };
        self
    }

    /// Run every cell through the descriptor-backed population engine
    /// with `n` clients (see `ExperimentBuilder::population`; combine
    /// with [`Campaign::simulated`] — population cells are timing-only).
    /// Population-scale sweeps make churn/strategy comparisons at
    /// realistic federation sizes a one-call affair.
    pub fn population(mut self, n: usize) -> Self {
        self.base.population = Some(crate::fl::launcher::PopulationOptions::of_size(n));
        self.base.clients = n;
        self
    }

    /// The sweep grid in run order — the one definition both
    /// [`Campaign::cells`] and [`Campaign::run`] iterate.
    fn grid(&self) -> Vec<(CampaignCell, &Scenario)> {
        let mut out = Vec::with_capacity(
            self.scenarios.len()
                * self.strategies.len()
                * self.attacks.len()
                * self.seeds.len(),
        );
        for scenario in &self.scenarios {
            for strategy in &self.strategies {
                for attack in &self.attacks {
                    for &seed in &self.seeds {
                        let cell = CampaignCell {
                            seed,
                            strategy: strategy.clone(),
                            scenario: scenario.name.clone(),
                            attack: attack.clone(),
                            cell_seed: attacked_cell_seed(
                                seed,
                                strategy,
                                &scenario.name,
                                attack.as_deref(),
                            ),
                        };
                        out.push((cell, scenario));
                    }
                }
            }
        }
        out
    }

    /// The sweep grid in run order: scenarios (outer) × strategies ×
    /// attacks × seeds (inner).
    pub fn cells(&self) -> Vec<CampaignCell> {
        self.grid().into_iter().map(|(cell, _)| cell).collect()
    }

    /// Run the whole sweep sequentially.  A cell that fails to build or
    /// run becomes an error row — one bad combination never aborts the
    /// campaign.
    pub fn run(&self) -> CampaignReport {
        let cells = self
            .grid()
            .into_iter()
            .map(|(cell, scenario)| self.run_cell(cell, scenario))
            .collect();
        CampaignReport { name: self.name.clone(), cells }
    }

    /// An order-sensitive fingerprint of the sweep grid (name, base
    /// shape, and every cell's coordinates + derived seed).  A resumed
    /// campaign must present the *same* grid the cursor was written
    /// against — resuming a different sweep into the directory is an
    /// error, not a silent partial merge.
    fn grid_hash(&self) -> u64 {
        let mut h = splitmix64(
            fnv1a64(&self.name)
                ^ splitmix64((self.base.rounds as u64) ^ ((self.base.clients as u64) << 32)),
        );
        for (cell, _) in self.grid() {
            h = splitmix64(
                h ^ cell.cell_seed
                    ^ fnv1a64(&cell.strategy).rotate_left(11)
                    ^ fnv1a64(&cell.scenario).rotate_left(23)
                    ^ fnv1a64(cell.attack.as_deref().unwrap_or("none")).rotate_left(37),
            );
        }
        h
    }

    fn cursor_error(dir: &Path, msg: &str) -> std::io::Error {
        std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("{}: {msg}", dir.join(CURSOR_FILE).display()),
        )
    }

    /// Atomically record `done` finished cells (temp file + fsync +
    /// rename, like `durable::Checkpoint::save`).
    fn write_cursor(&self, dir: &Path, done: usize) -> std::io::Result<()> {
        let tmp = dir.join("cursor.tmp");
        {
            let mut f = File::create(&tmp)?;
            f.write_all(
                format!("{CURSOR_HEADER}\n{:016x}\n{done}\n", self.grid_hash()).as_bytes(),
            )?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, dir.join(CURSOR_FILE))?;
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }

    fn read_cursor(&self, dir: &Path) -> std::io::Result<usize> {
        let text = std::fs::read_to_string(dir.join(CURSOR_FILE))?;
        let mut lines = text.lines();
        if lines.next() != Some(CURSOR_HEADER) {
            return Err(Self::cursor_error(dir, "not a campaign cursor"));
        }
        match lines.next() {
            Some(h) if h == format!("{:016x}", self.grid_hash()) => {}
            Some(_) => {
                return Err(Self::cursor_error(
                    dir,
                    "grid mismatch: this campaign's axes differ from the recorded run",
                ))
            }
            None => return Err(Self::cursor_error(dir, "missing grid hash")),
        }
        let done: usize = lines
            .next()
            .and_then(|n| n.trim().parse().ok())
            .ok_or_else(|| Self::cursor_error(dir, "missing or bad cell count"))?;
        if done > self.grid().len() {
            return Err(Self::cursor_error(dir, "cursor is past the end of the grid"));
        }
        Ok(done)
    }

    /// Run the sweep durably into `dir` (DESIGN.md §14): each finished
    /// cell's JSONL row is appended to `cells.jsonl` and fsynced, then an
    /// atomically-replaced cursor file records the finished-cell count,
    /// so a killed campaign loses at most the cell it was running.  Any
    /// previous recording in `dir` is restarted from scratch; use
    /// [`Campaign::resume_from`] to continue one.  The returned report
    /// covers the cells this call ran (here: all of them).
    pub fn run_durable(&self, dir: impl AsRef<Path>) -> std::io::Result<CampaignReport> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let file = File::create(dir.join(CELLS_FILE))?;
        self.write_cursor(dir, 0)?;
        self.run_cells_from(dir, file, 0)
    }

    /// Continue a durable campaign recorded in `dir`: validates that this
    /// campaign's grid matches the cursor's fingerprint, truncates
    /// `cells.jsonl` to the recorded number of complete rows (a torn row
    /// from a mid-append crash is discarded and its cell re-runs), and
    /// runs the remaining cells.  Per-cell seeds are coordinate-derived,
    /// so the merged `cells.jsonl` is byte-identical to an uninterrupted
    /// [`Campaign::run_durable`] — `tests/durable.rs` and the CI
    /// crash-recovery job both assert it.  The returned report covers
    /// only the cells this call ran.
    pub fn resume_from(&self, dir: impl AsRef<Path>) -> std::io::Result<CampaignReport> {
        let dir = dir.as_ref();
        let done = self.read_cursor(dir)?;
        let cells_path = dir.join(CELLS_FILE);
        let existing = std::fs::read_to_string(&cells_path).unwrap_or_default();
        let mut keep = 0usize;
        let mut complete = 0usize;
        for (i, b) in existing.bytes().enumerate() {
            if b == b'\n' {
                complete += 1;
                keep = i + 1;
                if complete == done {
                    break;
                }
            }
        }
        if complete < done {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!(
                    "{}: holds {complete} complete rows but the cursor records {done}",
                    cells_path.display()
                ),
            ));
        }
        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .truncate(false)
            .read(true)
            .write(true)
            .open(&cells_path)?;
        file.set_len(keep as u64)?;
        file.seek(SeekFrom::End(0))?;
        self.run_cells_from(dir, file, done)
    }

    /// The durable inner loop shared by fresh and resumed recordings.
    fn run_cells_from(
        &self,
        dir: &Path,
        mut file: File,
        done: usize,
    ) -> std::io::Result<CampaignReport> {
        let mut cells = Vec::new();
        for (i, (cell, scenario)) in self.grid().into_iter().enumerate() {
            if i < done {
                continue;
            }
            let outcome = self.run_cell(cell, scenario);
            file.write_all((outcome.to_json().dump() + "\n").as_bytes())?;
            file.sync_data()?;
            self.write_cursor(dir, i + 1)?;
            cells.push(outcome);
        }
        Ok(CampaignReport { name: self.name.clone(), cells })
    }

    fn run_cell(&self, cell: CampaignCell, scenario: &Scenario) -> CellOutcome {
        let mut opts = self.base.clone();
        opts.seed = cell.cell_seed;
        opts.strategy = cell.strategy.clone();
        opts.scenario = (!scenario.is_static()).then(|| scenario.clone());
        if let Some(name) = cell.attack.clone() {
            match AttackConfig::preset(&name) {
                Some(a) => opts.attack = Some(a),
                None => {
                    return CellOutcome {
                        cell,
                        rounds: 0,
                        final_train_loss: None,
                        eval_loss: None,
                        eval_accuracy: None,
                        total_emu_s: 0.0,
                        failures: 0,
                        metrics: None,
                        error: Some(format!(
                            "unknown attack preset '{name}' ({})",
                            ATTACK_PRESETS.join("|")
                        )),
                    }
                }
            }
        }
        // Every cell collects metrics: the simulated-domain registry is a
        // deterministic fold over the cell's event stream, so the JSONL
        // metric columns stay byte-identical across worker counts and
        // across campaign resume (DESIGN.md §17).
        let mut builder = ExperimentBuilder::from_options(opts).strict().metrics();
        if let ExecutionMode::Simulated { param_dim } = self.mode {
            builder = builder.simulated(param_dim);
        }
        let error_row = |cell: CampaignCell, msg: String| CellOutcome {
            cell,
            rounds: 0,
            final_train_loss: None,
            eval_loss: None,
            eval_accuracy: None,
            total_emu_s: 0.0,
            failures: 0,
            metrics: None,
            error: Some(msg),
        };
        let experiment = match builder.build() {
            Ok(e) => e,
            Err(e) => return error_row(cell, e.to_string()),
        };
        match experiment.run() {
            Ok(report) => {
                let (eval_loss, eval_accuracy) = match report.last_eval() {
                    Some((l, a)) => (Some(l), Some(a)),
                    None => (None, None),
                };
                CellOutcome {
                    cell,
                    rounds: report.history.rounds.len(),
                    final_train_loss: report.final_train_loss(),
                    eval_loss,
                    eval_accuracy,
                    total_emu_s: report.total_emu_s(),
                    failures: report.failures(),
                    metrics: report.metrics,
                    error: None,
                }
            }
            Err(e) => error_row(cell, e.to_string()),
        }
    }
}

/// Summary metrics of one finished (or failed) campaign cell.
#[derive(Debug, Clone)]
pub struct CellOutcome {
    /// The cell's sweep coordinates and derived seed.
    pub cell: CampaignCell,
    /// Rounds recorded (0 when the cell errored before running).
    pub rounds: usize,
    /// Final-round example-weighted training loss (None when the cell
    /// errored, or NaN-valued rounds left nothing finite).
    pub final_train_loss: Option<f32>,
    /// Last centralised evaluation loss, if evaluation ran.
    pub eval_loss: Option<f32>,
    /// Last centralised evaluation accuracy, if evaluation ran.
    pub eval_accuracy: Option<f32>,
    /// Total emulated federation seconds.
    pub total_emu_s: f64,
    /// Total client failures across rounds.
    pub failures: usize,
    /// The cell's run metrics (`None` for error rows).  Only the
    /// simulated-domain headline counters reach the JSONL row; the full
    /// registries stay here for programmatic consumers.
    pub metrics: Option<crate::obs::RunMetrics>,
    /// Build/run error, if the cell did not finish.
    pub error: Option<String>,
}

/// `NaN` exports as JSON `null` (an all-failed final round has NaN loss);
/// the same rule [`ExperimentReport::to_json`](super::experiment::ExperimentReport::to_json)
/// applies, via the shared helper.
fn opt_finite(x: Option<f32>) -> Json {
    x.map(|v| finite_num(v as f64)).unwrap_or(Json::Null)
}

impl CellOutcome {
    /// One flat JSON object — a single JSONL row.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            // u64 seeds don't survive the f64 round-trip JSON numbers
            // imply; export both exactly, as strings.
            ("seed", Json::str(self.cell.seed.to_string())),
            ("strategy", Json::str(self.cell.strategy.clone())),
            ("scenario", Json::str(self.cell.scenario.clone())),
            (
                "attack",
                Json::str(self.cell.attack.clone().unwrap_or_else(|| "none".into())),
            ),
            ("cell_seed", Json::str(self.cell.cell_seed.to_string())),
            ("rounds", Json::num(self.rounds as f64)),
            ("final_train_loss", opt_finite(self.final_train_loss)),
            ("eval_loss", opt_finite(self.eval_loss)),
            ("eval_accuracy", opt_finite(self.eval_accuracy)),
            ("total_emu_s", Json::num(self.total_emu_s)),
            ("failures", Json::num(self.failures as f64)),
            (
                "metrics",
                self.metrics
                    .as_ref()
                    .map(|m| {
                        // The simulated-domain headline set only — every
                        // value is a deterministic fold over the cell's
                        // event stream, so resumed and uninterrupted
                        // campaigns export byte-identical rows.
                        let c = |n: &str| Json::num(m.sim.counter(n) as f64);
                        Json::obj(vec![
                            ("attack_injections", c("attack_injections")),
                            ("clients_done", c("clients_done")),
                            ("clients_failed", c("clients_failed")),
                            ("clients_selected", c("clients_selected")),
                            ("comm_bytes_download", c("comm_bytes_download")),
                            ("comm_bytes_upload", c("comm_bytes_upload")),
                            (
                                "emu_seconds_total",
                                finite_num(m.sim.gauge("emu_seconds_total").unwrap_or(0.0)),
                            ),
                        ])
                    })
                    .unwrap_or(Json::Null),
            ),
            (
                "error",
                self.error.clone().map(Json::str).unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Every cell's outcome, in run order.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    /// The campaign's name.
    pub name: String,
    /// Per-cell outcomes (scenarios outer × strategies × seeds inner).
    pub cells: Vec<CellOutcome>,
}

impl CampaignReport {
    /// Cells that finished without error.
    pub fn succeeded(&self) -> usize {
        self.cells.iter().filter(|c| c.error.is_none()).count()
    }

    /// One compact JSON object per cell, newline-separated (JSONL).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for cell in &self.cells {
            out.push_str(&cell.to_json().dump());
            out.push('\n');
        }
        out
    }

    /// Write the JSONL export to `path`.
    pub fn write_jsonl(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_jsonl().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_seed_is_deterministic_and_axis_separated() {
        assert_eq!(cell_seed(7, "fedavg", "stable"), cell_seed(7, "fedavg", "stable"));
        assert_ne!(cell_seed(7, "fedavg", "stable"), cell_seed(8, "fedavg", "stable"));
        assert_ne!(cell_seed(7, "fedavg", "stable"), cell_seed(7, "krum", "stable"));
        assert_ne!(
            cell_seed(7, "fedavg", "high-churn"),
            cell_seed(7, "high-churn", "fedavg"),
            "strategy and scenario axes must not be interchangeable"
        );
    }

    #[test]
    fn cells_cover_the_grid_with_coordinate_derived_seeds() {
        let campaign = Campaign::new("t", LaunchOptions::default())
            .seeds(&[1, 2])
            .strategies(&["fedavg", "fedprox"])
            .scenarios(&[
                Scenario::preset("stable").unwrap(),
                Scenario::preset("high-churn").unwrap(),
            ]);
        let cells = campaign.cells();
        assert_eq!(cells.len(), 8);
        // Permuting a sweep axis must not change any cell's derived seed.
        let permuted = Campaign::new("t", LaunchOptions::default())
            .seeds(&[2, 1])
            .strategies(&["fedprox", "fedavg"])
            .scenarios(&[
                Scenario::preset("high-churn").unwrap(),
                Scenario::preset("stable").unwrap(),
            ]);
        for cell in &cells {
            let twin = permuted
                .cells()
                .into_iter()
                .find(|c| {
                    c.seed == cell.seed
                        && c.strategy == cell.strategy
                        && c.scenario == cell.scenario
                })
                .expect("same coordinates exist");
            assert_eq!(twin.cell_seed, cell.cell_seed);
        }
        // All distinct coordinates -> all distinct seeds.
        let mut seeds: Vec<u64> = cells.iter().map(|c| c.cell_seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 8);
    }

    #[test]
    fn population_cells_run_through_the_descriptor_engine() {
        // Batch 16 keeps the ResNet-18 timing footprint inside every
        // survey card's VRAM, so no all-OOM round can abort a cell.
        let base = LaunchOptions { batch: 16, fail_on_empty_round: false, ..Default::default() };
        let report = Campaign::new("pop", base)
            .seeds(&[1])
            .strategies(&["fedavg"])
            .scenarios(&[Scenario::preset("high-churn").unwrap()])
            .population(24)
            .simulated(16)
            .run();
        assert_eq!(report.cells.len(), 1);
        let cell = &report.cells[0];
        assert!(cell.error.is_none(), "{:?}", cell.error);
        assert!(cell.rounds > 0);
        // Population without simulated mode: an error row, not an abort.
        let report = Campaign::new("pop", LaunchOptions::default()).population(24).run();
        assert!(report.cells[0].error.as_deref().unwrap_or("").contains("simulated"));
    }

    #[test]
    fn attack_axis_expands_the_grid_and_separates_seeds() {
        let campaign = Campaign::new("adv", LaunchOptions::default())
            .seeds(&[1])
            .strategies(&["fedavg", "krum"])
            .attacks(&["none", "sign-flip"]);
        let cells = campaign.cells();
        assert_eq!(cells.len(), 4);
        // Honest cells keep the historical three-coordinate seed...
        let honest = cells
            .iter()
            .find(|c| c.attack.is_none() && c.strategy == "fedavg")
            .unwrap();
        assert_eq!(honest.cell_seed, cell_seed(1, "fedavg", "stable"));
        // ...while attacked cells mix in the fourth coordinate.
        let attacked = cells
            .iter()
            .find(|c| c.attack.is_some() && c.strategy == "fedavg")
            .unwrap();
        assert_ne!(attacked.cell_seed, honest.cell_seed);
        let mut seeds: Vec<u64> = cells.iter().map(|c| c.cell_seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 4, "all four coordinates must separate");
    }

    #[test]
    fn attack_cells_run_and_export_the_attack_column() {
        let base = LaunchOptions {
            rounds: 3,
            batch: 16,
            fail_on_empty_round: false,
            ..Default::default()
        };
        let report = Campaign::new("adv", base)
            .seeds(&[5])
            .strategies(&["fedavg"])
            .attacks(&["none", "gauss"])
            .simulated(16)
            .run();
        assert_eq!(report.cells.len(), 2);
        assert_eq!(report.succeeded(), 2, "{:?}", report.cells[0].error);
        let honest = report.cells[0].to_json();
        assert_eq!(honest.get("attack").unwrap().as_str(), Some("none"));
        let attacked = report.cells[1].to_json();
        assert_eq!(attacked.get("attack").unwrap().as_str(), Some("gauss"));
        // Every finished cell carries its simulated-domain metric row.
        let m = honest.get("metrics").expect("metrics row");
        assert!(m.get("clients_selected").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(m.get("attack_injections").unwrap().as_f64(), Some(0.0));
        assert!(
            attacked.get("metrics").unwrap().get("attack_injections").unwrap().as_f64()
                > Some(0.0)
        );
        // Unknown presets become error rows, not aborts.
        let bad = Campaign::new("adv", LaunchOptions::default())
            .attacks(&["rootkit"])
            .simulated(16)
            .run();
        assert!(
            bad.cells[0].error.as_deref().unwrap_or("").contains("rootkit"),
            "{:?}",
            bad.cells[0].error
        );
    }

    #[test]
    fn error_cells_become_rows_not_aborts() {
        let report = Campaign::new("t", LaunchOptions::default())
            .strategies(&["no-such-strategy"])
            .simulated(16)
            .run();
        assert_eq!(report.cells.len(), 1);
        assert_eq!(report.succeeded(), 0);
        let row = report.cells[0].to_json();
        assert!(row.get("error").unwrap().as_str().unwrap().contains("no-such-strategy"));
        assert!(matches!(row.get("metrics"), Some(Json::Null)), "error rows carry no metrics");
    }
}
