//! The BouquetFL integration layer — the paper's contribution, as glue:
//! every client `fit` is wrapped in a `RestrictedEnv` following Fig. 1's
//! lifecycle (spawn restricted environment → local training under limits →
//! communicate update → reset limits).
//!
//! `BouquetContext` is what the server hands each client for the duration
//! of one fit: the shared PJRT executor, the federation's virtual clock,
//! the host-machine description, and the environment policy.

use crate::emu::{EnvConfig, FitReport, RestrictedEnv, VirtualClock};
use crate::error::EmuError;
use crate::hardware::profile::HardwareProfile;
use crate::modelcost::WorkloadCost;
use crate::runtime::ModelExecutor;

use super::params::ParamScratch;

/// Shared per-fit context (executor + clock + host + env policy).
///
/// The executor is optional: timing-only federations (`SimClient` fleets,
/// scheduler benches, pool workers without an artifact directory) run the
/// whole Fig. 1 lifecycle without PJRT; `TrainClient` fails its fit with a
/// lifecycle error if no executor is present.
pub struct BouquetContext<'a> {
    pub executor: Option<&'a mut ModelExecutor>,
    pub clock: &'a mut VirtualClock,
    pub host: &'a HardwareProfile,
    pub env_cfg: EnvConfig,
    /// Recycled parameter buffers: clients draw their update vectors from
    /// here instead of allocating fresh ones each fit (the accumulator
    /// returns folded buffers to the same stash).  A default (cold)
    /// scratch is always valid — recycling is an optimisation, never a
    /// semantic.
    pub scratch: ParamScratch,
}

impl<'a> BouquetContext<'a> {
    /// Fig. 1: spawn a restricted environment for `target`, run `steps`
    /// training steps of `workload` under it, reset the limits, and return
    /// the emulated report.
    ///
    /// `exec(executor, step)` performs the real training step; an `Err`
    /// aborts the fit (surfaced as a lifecycle error — runtime failures are
    /// not hardware failures).
    #[allow(clippy::too_many_arguments)]
    pub fn run_restricted<F>(
        &mut self,
        target: &HardwareProfile,
        workload: &WorkloadCost,
        batch: u32,
        steps: u32,
        dataset_bytes: u64,
        mut exec: F,
    ) -> Result<FitReport, EmuError>
    where
        F: FnMut(Option<&mut ModelExecutor>, u32) -> Result<f32, String>,
    {
        // Spawn: apply hardware limits.
        let mut env = RestrictedEnv::spawn(target, self.host, self.env_cfg.clone())?;

        // Fit under the limits.  Runtime errors abort with a description.
        let mut runtime_failure: Option<String> = None;
        let mut executor = self.executor.as_deref_mut();
        let report = env.run_fit(
            self.clock,
            workload,
            batch,
            steps,
            dataset_bytes,
            |step| match exec(executor.as_deref_mut(), step) {
                Ok(loss) => loss,
                Err(e) => {
                    if runtime_failure.is_none() {
                        runtime_failure = Some(e);
                    }
                    f32::NAN
                }
            },
        );

        // Reset: limits are torn down whether the fit succeeded or not.
        env.teardown();

        if let Some(msg) = runtime_failure {
            return Err(EmuError::Lifecycle(format!("runtime failure during fit: {msg}")));
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emu::{active_env_count, EmulationMode, Isolation, Optimizer};
    use crate::hardware::profile::preset;
    use crate::modelcost::resnet::resnet18_cifar;

    fn env_cfg() -> EnvConfig {
        EnvConfig {
            mode: EmulationMode::HostRestriction,
            optimizer: Optimizer::Sgd,
            isolation: Isolation::Concurrent,
        }
    }

    // A context with a dummy executor is hard to build without artifacts;
    // these tests exercise the lifecycle through `RestrictedEnv` directly
    // (the executor-dependent path is covered by rust/tests/runtime_e2e.rs).
    #[test]
    fn limits_do_not_leak_on_oom() {
        let _g = crate::emu::env::env_counter_test_guard();
        let host = HardwareProfile::paper_host();
        let target = preset("budget-2019").unwrap();
        let before = active_env_count();
        let mut clock = VirtualClock::fast_forward();
        let mut env = RestrictedEnv::spawn(&target, &host, env_cfg()).unwrap();
        let w = resnet18_cifar();
        let err = env.run_fit(&mut clock, &w, 8192, 1, 0, |_| 0.0).unwrap_err();
        assert!(matches!(err, EmuError::GpuOom { .. }));
        env.teardown();
        assert_eq!(active_env_count(), before);
    }
}
