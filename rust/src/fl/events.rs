//! Typed event stream for the federated round loop.
//!
//! The server emits an [`FlEvent`] at every observable transition of a run
//! (round begin/end, client completion, dropout/late verdicts, scheduling,
//! aggregation, evaluation).  Anything that wants to watch a federation —
//! history recording, trace export, progress logging, a live dashboard, a
//! convergence early-stopper — implements [`FlObserver`] and attaches via
//! `ServerApp::with_observer` or `ExperimentBuilder::observer`.
//!
//! The built-in [`History`](super::history::History) and
//! [`Trace`](crate::sched::Trace) outputs are themselves implemented as
//! subscribers ([`HistoryObserver`], [`TraceObserver`]): the round loop no
//! longer writes them directly, it only emits events.
//!
//! Events are emitted in **selection order** once a round's completion
//! stream has drained, so the observed sequence is identical for any
//! `--workers N` — the same bit-identity invariant the engine itself keeps
//! (DESIGN.md §8).
#![deny(missing_docs)]

use crate::sched::{Schedule, Trace};

use super::history::{History, RoundRecord, DEADLINE_REASON_PREFIX, DROPOUT_REASON_PREFIX};

/// Why a selected client contributed no update this round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// Went offline mid-round before finishing its fit+upload window.
    Dropout,
    /// Finished training, but past the round deadline.
    Late,
    /// The fit itself failed (e.g. emulated GPU/host OOM).
    Fault,
}

impl FailureKind {
    /// Classify a recorded failure reason by its shared prefix
    /// (`fl::history::DROPOUT_REASON_PREFIX` / `DEADLINE_REASON_PREFIX`).
    pub fn classify(reason: &str) -> FailureKind {
        if reason.starts_with(DROPOUT_REASON_PREFIX) {
            FailureKind::Dropout
        } else if reason.starts_with(DEADLINE_REASON_PREFIX) {
            FailureKind::Late
        } else {
            FailureKind::Fault
        }
    }
}

/// Direction of a simulated transfer (the netsim communication layer,
/// DESIGN.md §12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommDirection {
    /// Server → client: the global model broadcast (shared egress).
    Download,
    /// Client → server: the codec-compressed update (shared ingress).
    Upload,
}

/// One observable transition of a federated run.
///
/// Variants borrow from the round loop's state — observers that need to
/// keep data past the callback must copy it out.
#[derive(Debug)]
pub enum FlEvent<'a> {
    /// The run is starting.
    RunBegin {
        /// Configured number of rounds.
        rounds: u32,
        /// Federation size (total clients, not per-round participants).
        clients: usize,
    },
    /// A round selected its participants and is about to fit them.
    RoundBegin {
        /// Round index (0-based).
        round: u32,
        /// Selected client roster indices, in selection order.
        selected: &'a [usize],
    },
    /// No federation member was online; the round was skipped and the
    /// timeline fast-forwarded to the next wakeup.
    RoundSkipped {
        /// Round index (0-based).
        round: u32,
        /// Emulated seconds waited for the next online member.
        wait_s: f64,
    },
    /// A selected client finished its fit and was folded into the
    /// streaming aggregate.
    ClientDone {
        /// Round index (0-based).
        round: u32,
        /// Client id.
        client: u32,
        /// Emulated fit + communication seconds.
        fit_s: f64,
    },
    /// A selected client contributed no update this round.
    ClientFailed {
        /// Round index (0-based).
        round: u32,
        /// Client id.
        client: u32,
        /// Dropout / late / fault classification.
        kind: FailureKind,
        /// The recorded failure reason.
        reason: &'a str,
    },
    /// A compromised client's update was perturbed by the configured
    /// attack model (DESIGN.md §13) — after codec decode, immediately
    /// before the aggregation fold.  Emitted in fold (= selection) order
    /// after the round's `ClientDone`/`ClientFailed` events.
    AttackInjected {
        /// Round index (0-based).
        round: u32,
        /// The compromised client's id.
        client: u32,
        /// Registered name of the attack model that perturbed the update.
        model: &'a str,
    },
    /// A simulated transfer began (netsim only; emitted once the round's
    /// communication timeline is known, before the round's
    /// `ClientDone`/`ClientFailed` events — a download pair for every
    /// *selected* client (a fit that later failed still fetched the
    /// model and contended), then an upload pair per successful fit,
    /// each phase in selection order).
    CommStarted {
        /// Round index (0-based).
        round: u32,
        /// Client id.
        client: u32,
        /// Download (model broadcast) or upload (update).
        direction: CommDirection,
        /// Round-relative emulated start time, seconds.
        at_s: f64,
        /// Bytes on the wire (post-codec for uploads).
        wire_bytes: u64,
    },
    /// A simulated transfer completed (netsim only; same ordering
    /// contract as [`FlEvent::CommStarted`]).
    CommFinished {
        /// Round index (0-based).
        round: u32,
        /// Client id.
        client: u32,
        /// Download (model broadcast) or upload (update).
        direction: CommDirection,
        /// Round-relative emulated completion time, seconds.
        at_s: f64,
    },
    /// The round's emulated wall-clock schedule was computed.
    RoundScheduled {
        /// Round index (0-based).
        round: u32,
        /// Emulated time at which the round started.
        base_s: f64,
        /// Per-client spans and the round makespan.
        schedule: &'a Schedule,
    },
    /// Surviving updates were aggregated into the next global model.
    Aggregated {
        /// Round index (0-based).
        round: u32,
        /// Number of client updates that reached the aggregate.
        survivors: usize,
    },
    /// Centralised evaluation ran this round.
    Evaluated {
        /// Round index (0-based).
        round: u32,
        /// Held-out loss.
        loss: f32,
        /// Held-out accuracy in [0, 1].
        accuracy: f32,
    },
    /// The round's record is final (last event of every round, including
    /// skipped and empty rounds).
    RoundEnd {
        /// The finished round's full record.
        record: &'a RoundRecord,
    },
    /// The run finished (last event of a successful run).
    RunEnd {
        /// Configured number of rounds.
        rounds: u32,
    },
}

/// A subscriber to the federated event stream.
///
/// Observers run synchronously on the server thread in attach order, after
/// the two built-in subscribers (history, trace).  They must not panic;
/// they cannot alter the run.
pub trait FlObserver: Send {
    /// Called for every [`FlEvent`] the round loop emits.
    fn on_event(&mut self, event: &FlEvent<'_>);
}

/// Built-in subscriber that records the run's [`History`] — one
/// [`RoundRecord`] per [`FlEvent::RoundEnd`].
#[derive(Debug, Default)]
pub struct HistoryObserver {
    history: History,
}

impl HistoryObserver {
    /// The recorded history so far.
    pub fn history(&self) -> &History {
        &self.history
    }

    /// Record an owned round record directly (what the round loop uses
    /// after broadcasting `RoundEnd` — the borrowing event path would
    /// force a deep clone per round).
    pub fn push(&mut self, record: RoundRecord) {
        self.history.push(record);
    }

    /// Consume the observer, yielding the recorded history.
    pub fn into_history(self) -> History {
        self.history
    }
}

impl FlObserver for HistoryObserver {
    fn on_event(&mut self, event: &FlEvent<'_>) {
        if let FlEvent::RoundEnd { record } = event {
            self.history.push((*record).clone());
        }
    }
}

/// Built-in subscriber that collects the emulated-timeline [`Trace`]
/// (Chrome-trace ready): schedule slots from [`FlEvent::RoundScheduled`]
/// (category `fit`), netsim transfers from [`FlEvent::CommStarted`] /
/// [`FlEvent::CommFinished`] pairs (category `comm`), and attack-injection
/// markers from [`FlEvent::AttackInjected`] (category `attack`).
///
/// Comm and attack events arrive round-relative before the round's
/// schedule is known, so they buffer until [`FlEvent::RoundScheduled`]
/// supplies the round base; rounds that never schedule (empty rounds)
/// drop their buffers at [`FlEvent::RoundEnd`].
#[derive(Debug, Default)]
pub struct TraceObserver {
    trace: Trace,
    /// Open transfers of the current round: (client, direction, start).
    comm_open: Vec<(u32, CommDirection, f64)>,
    /// Completed transfers of the current round, round-relative.
    comm_done: Vec<(u32, CommDirection, f64, f64)>,
    /// Attack injections of the current round: (client, model name).
    attacks: Vec<(u32, String)>,
}

impl TraceObserver {
    /// Consume the observer, yielding the collected trace.
    pub fn into_trace(self) -> Trace {
        self.trace
    }
}

impl FlObserver for TraceObserver {
    fn on_event(&mut self, event: &FlEvent<'_>) {
        match event {
            FlEvent::CommStarted { client, direction, at_s, .. } => {
                self.comm_open.push((*client, *direction, *at_s));
            }
            FlEvent::CommFinished { client, direction, at_s, .. } => {
                if let Some(i) = self
                    .comm_open
                    .iter()
                    .position(|&(c, d, _)| c == *client && d == *direction)
                {
                    let (c, d, start) = self.comm_open.remove(i);
                    self.comm_done.push((c, d, start, *at_s));
                }
            }
            FlEvent::AttackInjected { client, model, .. } => {
                self.attacks.push((*client, (*model).to_string()));
            }
            FlEvent::RoundScheduled { round, base_s, schedule } => {
                for &(c, s, e) in &schedule.spans {
                    self.trace.add(c, format!("round{round}"), base_s + s, base_s + e);
                }
                for (c, d, start, end) in self.comm_done.drain(..) {
                    let label = match d {
                        CommDirection::Download => "downlink",
                        CommDirection::Upload => "uplink",
                    };
                    self.trace.add_cat(c, label, "comm", base_s + start, base_s + end);
                }
                let close_s = base_s + schedule.round_s;
                for (c, model) in self.attacks.drain(..) {
                    self.trace.add_cat(c, model, "attack", close_s, close_s);
                }
            }
            FlEvent::RoundEnd { .. } => {
                self.comm_open.clear();
                self.comm_done.clear();
                self.attacks.clear();
            }
            _ => {}
        }
    }
}

/// How often (in finished rounds) [`ProgressLogger`] emits a metric
/// snapshot line alongside the per-round lines.
const PROGRESS_SNAPSHOT_EVERY: u32 = 10;

/// Built-in subscriber that logs round progress through the crate logger
/// (`BOUQUET_LOG=info`); attach via `ExperimentBuilder::progress(true)`.
///
/// Tracks the emulated clock to report rounds/s throughput and an ETA for
/// the remaining rounds, emits a counters snapshot every
/// [`PROGRESS_SNAPSHOT_EVERY`] rounds, and flushes stderr at
/// [`FlEvent::RunEnd`] so the final summary line survives an immediate
/// process exit.
#[derive(Debug, Default)]
pub struct ProgressLogger {
    rounds_planned: u32,
    rounds_done: u32,
    emu_s: f64,
    selected: u64,
    done: u64,
    failed: u64,
    injected: u64,
}

impl FlObserver for ProgressLogger {
    fn on_event(&mut self, event: &FlEvent<'_>) {
        match event {
            FlEvent::RunBegin { rounds, clients } => {
                self.rounds_planned = *rounds;
                crate::log_info!("run: {clients} clients, {rounds} rounds");
            }
            FlEvent::RoundBegin { selected, .. } => {
                self.selected += selected.len() as u64;
            }
            FlEvent::ClientDone { .. } => {
                self.done += 1;
            }
            FlEvent::RoundEnd { record } => {
                self.rounds_done += 1;
                self.emu_s += record.emu_round_s;
                // Throughput and ETA on the EMULATED clock: rounds per
                // emulated second and emulated seconds left at the
                // average round length so far.
                let rps = if self.emu_s > 0.0 { self.rounds_done as f64 / self.emu_s } else { 0.0 };
                let remaining = self.rounds_planned.saturating_sub(self.rounds_done);
                let eta_s =
                    if rps > 0.0 { remaining as f64 / rps } else { 0.0 };
                crate::log_info!(
                    "round {}: {} selected, {} failed, train loss {:.4}, {:.2}s emulated \
                     ({:.3} rounds/s emu, eta {:.0}s emu)",
                    record.round,
                    record.selected.len(),
                    record.failures.len(),
                    record.train_loss,
                    record.emu_round_s,
                    rps,
                    eta_s
                );
                if self.rounds_done % PROGRESS_SNAPSHOT_EVERY == 0 {
                    crate::log_info!(
                        "progress: {}/{} rounds, {:.2}s emulated; clients {} selected, \
                         {} done, {} failed, {} injected",
                        self.rounds_done,
                        self.rounds_planned,
                        self.emu_s,
                        self.selected,
                        self.done,
                        self.failed,
                        self.injected
                    );
                }
            }
            FlEvent::ClientFailed { round, client, kind, .. } => {
                self.failed += 1;
                crate::log_debug!("round {round}: client {client} failed ({kind:?})");
            }
            FlEvent::AttackInjected { round, client, model } => {
                self.injected += 1;
                crate::log_debug!("round {round}: client {client} injected ({model})");
            }
            FlEvent::Evaluated { round, loss, accuracy } => {
                crate::log_info!(
                    "round {round}: eval loss {loss:.4}, accuracy {:.1}%",
                    accuracy * 100.0
                );
            }
            FlEvent::RunEnd { rounds } => {
                crate::log_info!(
                    "run done: {rounds} rounds, {:.2}s emulated; clients {} selected, \
                     {} done, {} failed, {} injected",
                    self.emu_s,
                    self.selected,
                    self.done,
                    self.failed,
                    self.injected
                );
                // The logger macros write line-buffered stderr; flush so
                // the final line is not dropped when the process exits
                // right after the run.
                let _ = std::io::Write::flush(&mut std::io::stderr());
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: u32) -> RoundRecord {
        RoundRecord {
            round,
            selected: vec![0, 1],
            failures: vec![],
            train_loss: 1.0,
            eval_loss: None,
            eval_accuracy: None,
            emu_round_s: 2.0,
            host_round_s: 0.0,
        }
    }

    #[test]
    fn history_observer_records_round_ends_only() {
        let mut obs = HistoryObserver::default();
        obs.on_event(&FlEvent::RunBegin { rounds: 2, clients: 2 });
        obs.on_event(&FlEvent::RoundBegin { round: 0, selected: &[0, 1] });
        let r0 = record(0);
        obs.on_event(&FlEvent::RoundEnd { record: &r0 });
        let r1 = record(1);
        obs.on_event(&FlEvent::RoundEnd { record: &r1 });
        obs.on_event(&FlEvent::RunEnd { rounds: 2 });
        let h = obs.into_history();
        assert_eq!(h.rounds.len(), 2);
        assert_eq!(h.rounds[1].round, 1);
    }

    #[test]
    fn trace_observer_replays_schedule_spans_at_the_round_base() {
        let schedule = Schedule {
            round_s: 3.0,
            spans: vec![(0, 0.0, 1.0), (1, 1.0, 3.0)],
        };
        let mut obs = TraceObserver::default();
        obs.on_event(&FlEvent::RoundScheduled { round: 2, base_s: 10.0, schedule: &schedule });
        let t = obs.into_trace();
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[0].label, "round2");
        assert_eq!(t.events[1].t_start_s, 11.0);
        assert_eq!(t.events[1].t_end_s, 13.0);
    }

    #[test]
    fn trace_observer_emits_comm_and_attack_rows_in_chrome_json() {
        let schedule = Schedule {
            round_s: 5.0,
            spans: vec![(0, 0.0, 5.0)],
        };
        let mut obs = TraceObserver::default();
        obs.on_event(&FlEvent::CommStarted {
            round: 1,
            client: 0,
            direction: CommDirection::Download,
            at_s: 0.0,
            wire_bytes: 1000,
        });
        obs.on_event(&FlEvent::CommFinished {
            round: 1,
            client: 0,
            direction: CommDirection::Download,
            at_s: 1.0,
        });
        obs.on_event(&FlEvent::CommStarted {
            round: 1,
            client: 0,
            direction: CommDirection::Upload,
            at_s: 3.0,
            wire_bytes: 200,
        });
        obs.on_event(&FlEvent::CommFinished {
            round: 1,
            client: 0,
            direction: CommDirection::Upload,
            at_s: 4.5,
        });
        obs.on_event(&FlEvent::AttackInjected { round: 1, client: 0, model: "sign-flip" });
        obs.on_event(&FlEvent::RoundScheduled { round: 1, base_s: 10.0, schedule: &schedule });
        let rows = obs.into_trace().to_chrome_json();
        let rows = rows.as_arr().unwrap();
        // One schedule slot + two comm spans + one attack marker.
        assert_eq!(rows.len(), 4);
        let cat = |i: usize| rows[i].get("cat").unwrap().as_str().unwrap().to_string();
        let name = |i: usize| rows[i].get("name").unwrap().as_str().unwrap().to_string();
        let ts = |i: usize| rows[i].get("ts").unwrap().as_f64().unwrap();
        let dur = |i: usize| rows[i].get("dur").unwrap().as_f64().unwrap();
        assert_eq!((cat(0), name(0)), ("fit".into(), "round1".into()));
        // Downlink rebased to the round base: [10.0, 11.0].
        assert_eq!((cat(1), name(1)), ("comm".into(), "downlink".into()));
        assert_eq!((ts(1), dur(1)), (10.0 * 1e6, 1.0 * 1e6));
        assert_eq!((cat(2), name(2)), ("comm".into(), "uplink".into()));
        assert_eq!((ts(2), dur(2)), (13.0 * 1e6, 1.5 * 1e6));
        // Attack marker: zero-length at the round close (10 + 5).
        assert_eq!((cat(3), name(3)), ("attack".into(), "sign-flip".into()));
        assert_eq!((ts(3), dur(3)), (15.0 * 1e6, 0.0));
    }

    #[test]
    fn trace_observer_drops_buffers_of_rounds_that_never_schedule() {
        let mut obs = TraceObserver::default();
        obs.on_event(&FlEvent::CommStarted {
            round: 0,
            client: 0,
            direction: CommDirection::Download,
            at_s: 0.0,
            wire_bytes: 10,
        });
        obs.on_event(&FlEvent::CommFinished {
            round: 0,
            client: 0,
            direction: CommDirection::Download,
            at_s: 1.0,
        });
        obs.on_event(&FlEvent::AttackInjected { round: 0, client: 0, model: "gauss" });
        // Empty round: RoundEnd arrives without RoundScheduled.
        let r = record(0);
        obs.on_event(&FlEvent::RoundEnd { record: &r });
        let schedule = Schedule { round_s: 1.0, spans: vec![(1, 0.0, 1.0)] };
        obs.on_event(&FlEvent::RoundScheduled { round: 1, base_s: 2.0, schedule: &schedule });
        let t = obs.into_trace();
        assert_eq!(t.events.len(), 1, "stale comm/attack rows leaked into the next round");
        assert_eq!(t.events[0].label, "round1");
    }

    #[test]
    fn failure_kind_classifies_by_reason_prefix() {
        assert_eq!(
            FailureKind::classify("dropout: client went offline at 3.00s"),
            FailureKind::Dropout
        );
        assert_eq!(
            FailureKind::classify("deadline: fit+comm would finish at 99.00s"),
            FailureKind::Late
        );
        assert_eq!(FailureKind::classify("GPU OOM on gtx-1060"), FailureKind::Fault);
    }
}
