//! Typed event stream for the federated round loop.
//!
//! The server emits an [`FlEvent`] at every observable transition of a run
//! (round begin/end, client completion, dropout/late verdicts, scheduling,
//! aggregation, evaluation).  Anything that wants to watch a federation —
//! history recording, trace export, progress logging, a live dashboard, a
//! convergence early-stopper — implements [`FlObserver`] and attaches via
//! `ServerApp::with_observer` or `ExperimentBuilder::observer`.
//!
//! The built-in [`History`](super::history::History) and
//! [`Trace`](crate::sched::Trace) outputs are themselves implemented as
//! subscribers ([`HistoryObserver`], [`TraceObserver`]): the round loop no
//! longer writes them directly, it only emits events.
//!
//! Events are emitted in **selection order** once a round's completion
//! stream has drained, so the observed sequence is identical for any
//! `--workers N` — the same bit-identity invariant the engine itself keeps
//! (DESIGN.md §8).
#![deny(missing_docs)]

use crate::sched::{Schedule, Trace};

use super::history::{History, RoundRecord, DEADLINE_REASON_PREFIX, DROPOUT_REASON_PREFIX};

/// Why a selected client contributed no update this round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// Went offline mid-round before finishing its fit+upload window.
    Dropout,
    /// Finished training, but past the round deadline.
    Late,
    /// The fit itself failed (e.g. emulated GPU/host OOM).
    Fault,
}

impl FailureKind {
    /// Classify a recorded failure reason by its shared prefix
    /// (`fl::history::DROPOUT_REASON_PREFIX` / `DEADLINE_REASON_PREFIX`).
    pub fn classify(reason: &str) -> FailureKind {
        if reason.starts_with(DROPOUT_REASON_PREFIX) {
            FailureKind::Dropout
        } else if reason.starts_with(DEADLINE_REASON_PREFIX) {
            FailureKind::Late
        } else {
            FailureKind::Fault
        }
    }
}

/// Direction of a simulated transfer (the netsim communication layer,
/// DESIGN.md §12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommDirection {
    /// Server → client: the global model broadcast (shared egress).
    Download,
    /// Client → server: the codec-compressed update (shared ingress).
    Upload,
}

/// One observable transition of a federated run.
///
/// Variants borrow from the round loop's state — observers that need to
/// keep data past the callback must copy it out.
#[derive(Debug)]
pub enum FlEvent<'a> {
    /// The run is starting.
    RunBegin {
        /// Configured number of rounds.
        rounds: u32,
        /// Federation size (total clients, not per-round participants).
        clients: usize,
    },
    /// A round selected its participants and is about to fit them.
    RoundBegin {
        /// Round index (0-based).
        round: u32,
        /// Selected client roster indices, in selection order.
        selected: &'a [usize],
    },
    /// No federation member was online; the round was skipped and the
    /// timeline fast-forwarded to the next wakeup.
    RoundSkipped {
        /// Round index (0-based).
        round: u32,
        /// Emulated seconds waited for the next online member.
        wait_s: f64,
    },
    /// A selected client finished its fit and was folded into the
    /// streaming aggregate.
    ClientDone {
        /// Round index (0-based).
        round: u32,
        /// Client id.
        client: u32,
        /// Emulated fit + communication seconds.
        fit_s: f64,
    },
    /// A selected client contributed no update this round.
    ClientFailed {
        /// Round index (0-based).
        round: u32,
        /// Client id.
        client: u32,
        /// Dropout / late / fault classification.
        kind: FailureKind,
        /// The recorded failure reason.
        reason: &'a str,
    },
    /// A compromised client's update was perturbed by the configured
    /// attack model (DESIGN.md §13) — after codec decode, immediately
    /// before the aggregation fold.  Emitted in fold (= selection) order
    /// after the round's `ClientDone`/`ClientFailed` events.
    AttackInjected {
        /// Round index (0-based).
        round: u32,
        /// The compromised client's id.
        client: u32,
        /// Registered name of the attack model that perturbed the update.
        model: &'a str,
    },
    /// A simulated transfer began (netsim only; emitted once the round's
    /// communication timeline is known, before the round's
    /// `ClientDone`/`ClientFailed` events — a download pair for every
    /// *selected* client (a fit that later failed still fetched the
    /// model and contended), then an upload pair per successful fit,
    /// each phase in selection order).
    CommStarted {
        /// Round index (0-based).
        round: u32,
        /// Client id.
        client: u32,
        /// Download (model broadcast) or upload (update).
        direction: CommDirection,
        /// Round-relative emulated start time, seconds.
        at_s: f64,
        /// Bytes on the wire (post-codec for uploads).
        wire_bytes: u64,
    },
    /// A simulated transfer completed (netsim only; same ordering
    /// contract as [`FlEvent::CommStarted`]).
    CommFinished {
        /// Round index (0-based).
        round: u32,
        /// Client id.
        client: u32,
        /// Download (model broadcast) or upload (update).
        direction: CommDirection,
        /// Round-relative emulated completion time, seconds.
        at_s: f64,
    },
    /// The round's emulated wall-clock schedule was computed.
    RoundScheduled {
        /// Round index (0-based).
        round: u32,
        /// Emulated time at which the round started.
        base_s: f64,
        /// Per-client spans and the round makespan.
        schedule: &'a Schedule,
    },
    /// Surviving updates were aggregated into the next global model.
    Aggregated {
        /// Round index (0-based).
        round: u32,
        /// Number of client updates that reached the aggregate.
        survivors: usize,
    },
    /// Centralised evaluation ran this round.
    Evaluated {
        /// Round index (0-based).
        round: u32,
        /// Held-out loss.
        loss: f32,
        /// Held-out accuracy in [0, 1].
        accuracy: f32,
    },
    /// The round's record is final (last event of every round, including
    /// skipped and empty rounds).
    RoundEnd {
        /// The finished round's full record.
        record: &'a RoundRecord,
    },
    /// The run finished (last event of a successful run).
    RunEnd {
        /// Configured number of rounds.
        rounds: u32,
    },
}

/// A subscriber to the federated event stream.
///
/// Observers run synchronously on the server thread in attach order, after
/// the two built-in subscribers (history, trace).  They must not panic;
/// they cannot alter the run.
pub trait FlObserver: Send {
    /// Called for every [`FlEvent`] the round loop emits.
    fn on_event(&mut self, event: &FlEvent<'_>);
}

/// Built-in subscriber that records the run's [`History`] — one
/// [`RoundRecord`] per [`FlEvent::RoundEnd`].
#[derive(Debug, Default)]
pub struct HistoryObserver {
    history: History,
}

impl HistoryObserver {
    /// The recorded history so far.
    pub fn history(&self) -> &History {
        &self.history
    }

    /// Record an owned round record directly (what the round loop uses
    /// after broadcasting `RoundEnd` — the borrowing event path would
    /// force a deep clone per round).
    pub fn push(&mut self, record: RoundRecord) {
        self.history.push(record);
    }

    /// Consume the observer, yielding the recorded history.
    pub fn into_history(self) -> History {
        self.history
    }
}

impl FlObserver for HistoryObserver {
    fn on_event(&mut self, event: &FlEvent<'_>) {
        if let FlEvent::RoundEnd { record } = event {
            self.history.push((*record).clone());
        }
    }
}

/// Built-in subscriber that collects the emulated-timeline [`Trace`] from
/// [`FlEvent::RoundScheduled`] events (Chrome-trace ready).
#[derive(Debug, Default)]
pub struct TraceObserver {
    trace: Trace,
}

impl TraceObserver {
    /// Consume the observer, yielding the collected trace.
    pub fn into_trace(self) -> Trace {
        self.trace
    }
}

impl FlObserver for TraceObserver {
    fn on_event(&mut self, event: &FlEvent<'_>) {
        if let FlEvent::RoundScheduled { round, base_s, schedule } = event {
            for &(c, s, e) in &schedule.spans {
                self.trace.add(c, format!("round{round}"), base_s + s, base_s + e);
            }
        }
    }
}

/// Built-in subscriber that logs round progress through the crate logger
/// (`BOUQUET_LOG=info`); attach via `ExperimentBuilder::progress(true)`.
#[derive(Debug, Default)]
pub struct ProgressLogger;

impl FlObserver for ProgressLogger {
    fn on_event(&mut self, event: &FlEvent<'_>) {
        match event {
            FlEvent::RunBegin { rounds, clients } => {
                crate::log_info!("run: {clients} clients, {rounds} rounds");
            }
            FlEvent::RoundEnd { record } => {
                crate::log_info!(
                    "round {}: {} selected, {} failed, train loss {:.4}, {:.2}s emulated",
                    record.round,
                    record.selected.len(),
                    record.failures.len(),
                    record.train_loss,
                    record.emu_round_s
                );
            }
            FlEvent::ClientFailed { round, client, kind, .. } => {
                crate::log_debug!("round {round}: client {client} failed ({kind:?})");
            }
            FlEvent::AttackInjected { round, client, model } => {
                crate::log_debug!("round {round}: client {client} injected ({model})");
            }
            FlEvent::Evaluated { round, loss, accuracy } => {
                crate::log_info!(
                    "round {round}: eval loss {loss:.4}, accuracy {:.1}%",
                    accuracy * 100.0
                );
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: u32) -> RoundRecord {
        RoundRecord {
            round,
            selected: vec![0, 1],
            failures: vec![],
            train_loss: 1.0,
            eval_loss: None,
            eval_accuracy: None,
            emu_round_s: 2.0,
            host_round_s: 0.0,
        }
    }

    #[test]
    fn history_observer_records_round_ends_only() {
        let mut obs = HistoryObserver::default();
        obs.on_event(&FlEvent::RunBegin { rounds: 2, clients: 2 });
        obs.on_event(&FlEvent::RoundBegin { round: 0, selected: &[0, 1] });
        let r0 = record(0);
        obs.on_event(&FlEvent::RoundEnd { record: &r0 });
        let r1 = record(1);
        obs.on_event(&FlEvent::RoundEnd { record: &r1 });
        obs.on_event(&FlEvent::RunEnd { rounds: 2 });
        let h = obs.into_history();
        assert_eq!(h.rounds.len(), 2);
        assert_eq!(h.rounds[1].round, 1);
    }

    #[test]
    fn trace_observer_replays_schedule_spans_at_the_round_base() {
        let schedule = Schedule {
            round_s: 3.0,
            spans: vec![(0, 0.0, 1.0), (1, 1.0, 3.0)],
        };
        let mut obs = TraceObserver::default();
        obs.on_event(&FlEvent::RoundScheduled { round: 2, base_s: 10.0, schedule: &schedule });
        let t = obs.into_trace();
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[0].label, "round2");
        assert_eq!(t.events[1].t_start_s, 11.0);
        assert_eq!(t.events[1].t_end_s, 13.0);
    }

    #[test]
    fn failure_kind_classifies_by_reason_prefix() {
        assert_eq!(
            FailureKind::classify("dropout: client went offline at 3.00s"),
            FailureKind::Dropout
        );
        assert_eq!(
            FailureKind::classify("deadline: fit+comm would finish at 99.00s"),
            FailureKind::Late
        );
        assert_eq!(FailureKind::classify("GPU OOM on gtx-1060"), FailureKind::Fault);
    }
}
