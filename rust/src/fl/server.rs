//! The federated server (Flower's `ServerApp` analogue): round loop,
//! client selection, BouquetFL-restricted fits, failure handling,
//! aggregation, centralised evaluation, history.

use std::time::Instant;

use crate::data::Dataset;
use crate::emu::{EnvConfig, Isolation, VirtualClock};
use crate::error::{EmuError, FlError};
use crate::hardware::profile::HardwareProfile;
use crate::runtime::ModelExecutor;
use crate::sched::{Durations, Scheduler, Trace};

use super::bouquet::BouquetContext;
use super::client::{ClientApp, FitConfig, FitResult};
use super::clientmgr::{ClientManager, Selection};
use super::history::{FailureRecord, History, RoundRecord};
use super::params::ParamVector;
use super::strategy::Strategy;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub rounds: u32,
    pub selection: Selection,
    pub fit: FitConfig,
    /// Run centralised evaluation every N rounds (0 = never).
    pub eval_every: u32,
    pub seed: u64,
    /// Abort if a round ends with zero surviving clients.
    pub fail_on_empty_round: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            rounds: 10,
            selection: Selection::All,
            fit: FitConfig::default(),
            eval_every: 5,
            seed: 42,
            fail_on_empty_round: true,
        }
    }
}

/// The federated server.
pub struct ServerApp<'a> {
    pub cfg: ServerConfig,
    pub host: HardwareProfile,
    pub env_cfg: EnvConfig,
    strategy: Box<dyn Strategy>,
    scheduler: Box<dyn Scheduler>,
    clients: Vec<Box<dyn ClientApp + 'a>>,
    /// Held-out evaluation data (centralised, on the server).
    eval_data: Option<Dataset>,
    pub trace: Trace,
}

impl<'a> ServerApp<'a> {
    pub fn new(
        cfg: ServerConfig,
        host: HardwareProfile,
        strategy: Box<dyn Strategy>,
        scheduler: Box<dyn Scheduler>,
        clients: Vec<Box<dyn ClientApp + 'a>>,
    ) -> Self {
        // The paper's §3: hardware controls are global; only the
        // limited-parallel extension may relax isolation.
        let isolation = if scheduler.max_concurrency() > 1 {
            Isolation::Concurrent
        } else {
            Isolation::Strict
        };
        ServerApp {
            cfg,
            host,
            env_cfg: EnvConfig { isolation, ..Default::default() },
            strategy,
            scheduler,
            clients,
            eval_data: None,
            trace: Trace::default(),
        }
    }

    pub fn with_eval_data(mut self, data: Dataset) -> Self {
        self.eval_data = Some(data);
        self
    }

    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    /// Run the federation; returns the training history.
    pub fn run(
        &mut self,
        executor: &mut ModelExecutor,
        clock: &mut VirtualClock,
    ) -> Result<(ParamVector, History), FlError> {
        if self.clients.is_empty() {
            return Err(FlError::NoClients { round: 0 });
        }
        let mut global = executor
            .init_params(self.cfg.seed as i32)
            .map_err(|e| FlError::Strategy(format!("init failed: {e}")))?;
        let mut history = History::default();
        let mut manager = ClientManager::new(self.cfg.seed, self.cfg.selection);

        for round in 0..self.cfg.rounds {
            let host_t0 = Instant::now();
            let selected = manager.select(self.clients.len());
            let fit_cfg = self.strategy.configure(round, &self.cfg.fit);

            // --- fit phase (sequential real execution; see sched/) -------
            let mut results: Vec<FitResult> = Vec::new();
            let mut failures: Vec<FailureRecord> = Vec::new();
            let mut durations: Durations = Vec::new();
            let round_t0 = clock.now_s();
            for &ci in &selected {
                let client = &mut self.clients[ci];
                let mut ctx = BouquetContext {
                    executor,
                    clock,
                    host: &self.host,
                    env_cfg: self.env_cfg.clone(),
                };
                match client.fit(&global, &fit_cfg, &mut ctx) {
                    Ok(result) => {
                        durations.push((
                            result.client,
                            result.emu.emu_total_s + result.comm_s,
                        ));
                        results.push(result);
                    }
                    Err(e @ EmuError::GpuOom { .. })
                    | Err(e @ EmuError::HostOom { .. }) => {
                        // The paper's OOM story: the framework survives a
                        // failing client; it simply contributes no update.
                        failures.push(FailureRecord {
                            client: client.id(),
                            reason: e.to_string(),
                        });
                    }
                    Err(other) => {
                        return Err(FlError::ClientFailed {
                            client: client.id(),
                            source: other,
                        })
                    }
                }
            }

            if results.is_empty() {
                if self.cfg.fail_on_empty_round {
                    return Err(FlError::AllClientsFailed {
                        round,
                        count: selected.len(),
                    });
                }
                history.push(RoundRecord {
                    round,
                    selected: selected.iter().map(|&i| i as u32).collect(),
                    failures,
                    train_loss: f32::NAN,
                    eval_loss: None,
                    eval_accuracy: None,
                    emu_round_s: 0.0,
                    host_round_s: host_t0.elapsed().as_secs_f64(),
                });
                continue;
            }

            // --- round wall-clock per the scheduling policy --------------
            let schedule = self.scheduler.schedule(&durations);
            let base = round_t0;
            for &(c, s, e) in &schedule.spans {
                self.trace.add(c, format!("round{round}"), base + s, base + e);
            }

            // --- aggregate ------------------------------------------------
            global = self.strategy.aggregate(&global, &results, executor)?;

            // --- evaluate -------------------------------------------------
            let (eval_loss, eval_accuracy) = if self.cfg.eval_every > 0
                && (round + 1) % self.cfg.eval_every == 0
            {
                match self.evaluate(executor, &global) {
                    Some((l, a)) => (Some(l), Some(a)),
                    None => (None, None),
                }
            } else {
                (None, None)
            };

            let total_examples: usize = results.iter().map(|r| r.num_examples).sum();
            let train_loss = results
                .iter()
                .map(|r| r.mean_loss * r.num_examples as f32)
                .sum::<f32>()
                / total_examples as f32;

            history.push(RoundRecord {
                round,
                selected: selected.iter().map(|&i| i as u32).collect(),
                failures,
                train_loss,
                eval_loss,
                eval_accuracy,
                emu_round_s: schedule.round_s,
                host_round_s: host_t0.elapsed().as_secs_f64(),
            });
        }
        Ok((global, history))
    }

    /// Centralised eval over the held-out set (batched by the compiled
    /// eval artifact's batch size; a trailing partial batch is padded by
    /// wrapping, standard practice for fixed-shape accelerator eval).
    fn evaluate(
        &self,
        executor: &mut ModelExecutor,
        global: &ParamVector,
    ) -> Option<(f32, f32)> {
        let data = self.eval_data.as_ref()?;
        let batch = executor.eval_batch_size()?;
        let n = data.len();
        if n == 0 {
            return None;
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut seen = 0usize;
        let mut start = 0usize;
        while seen < n {
            let idx: Vec<usize> = (0..batch as usize).map(|i| (start + i) % n).collect();
            let (x, y) = data.gather(&idx);
            let take = (batch as usize).min(n - seen);
            match executor.eval_batch(global, &x, &y, batch) {
                Ok((l, c)) => {
                    // Only count the non-wrapped fraction.
                    let frac = take as f64 / batch as f64;
                    loss_sum += l as f64 * take as f64;
                    correct += c as f64 * frac;
                }
                Err(_) => return None,
            }
            seen += take;
            start += take;
        }
        Some(((loss_sum / n as f64) as f32, (correct / n as f64) as f32))
    }
}
