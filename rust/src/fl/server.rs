//! The federated server (Flower's `ServerApp` analogue): round loop,
//! client selection, BouquetFL-restricted fits, failure handling,
//! streaming aggregation, centralised evaluation, history.
//!
//! The round loop consumes a *completion stream* of fit outcomes instead
//! of collecting a `Vec<FitResult>`: each finished client is folded into
//! the strategy's [`AggAccumulator`] and dropped, so peak memory for the
//! mean-family strategies is O(params) regardless of federation size
//! (DESIGN.md §8).  With `with_round_engine(workers > 1, ..)` the fits
//! themselves run concurrently on a [`WorkerPool`]; a reorder buffer
//! restores selection order before folding, so the aggregate, the emulated
//! `Schedule`, and the shared clock are bit-identical to the sequential
//! engine.

use std::sync::Arc;
use std::time::Instant;

use crate::data::Dataset;
use crate::emu::{EnvConfig, Isolation, VirtualClock};
use crate::error::{EmuError, FlError};
use crate::hardware::profile::HardwareProfile;
use crate::runtime::ModelExecutor;
use crate::sched::pool::FitOutcomeSlim;
use crate::sched::{ExecutorFactory, FitTask, ReorderBuffer, Scheduler, Trace, WorkerPool};

use super::bouquet::BouquetContext;
use super::client::{ClientApp, FitConfig, FitResult};
use super::clientmgr::{ClientManager, RoundLedger, Selection};
use super::history::{History, RoundRecord};
use super::params::ParamVector;
use super::strategy::{AggAccumulator, Strategy};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub rounds: u32,
    pub selection: Selection,
    pub fit: FitConfig,
    /// Run centralised evaluation every N rounds (0 = never).
    pub eval_every: u32,
    pub seed: u64,
    /// Abort if a round ends with zero surviving clients.
    pub fail_on_empty_round: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            rounds: 10,
            selection: Selection::All,
            fit: FitConfig::default(),
            eval_every: 5,
            seed: 42,
            fail_on_empty_round: true,
        }
    }
}

/// The federated server.
pub struct ServerApp {
    pub cfg: ServerConfig,
    pub host: HardwareProfile,
    pub env_cfg: EnvConfig,
    strategy: Box<dyn Strategy>,
    scheduler: Box<dyn Scheduler>,
    /// `None` marks a client currently checked out to a fit worker.
    clients: Vec<Option<Box<dyn ClientApp>>>,
    /// Held-out evaluation data (centralised, on the server).
    eval_data: Option<Dataset>,
    /// Real-execution concurrency (1 = in-thread sequential fits).
    workers: usize,
    /// Per-worker executor builder for the concurrent engine.
    executor_factory: Option<ExecutorFactory>,
    pub trace: Trace,
}

impl ServerApp {
    pub fn new(
        cfg: ServerConfig,
        host: HardwareProfile,
        strategy: Box<dyn Strategy>,
        scheduler: Box<dyn Scheduler>,
        clients: Vec<Box<dyn ClientApp>>,
    ) -> Self {
        // The paper's §3: hardware controls are global; only the
        // limited-parallel extension may relax isolation.
        let isolation = if scheduler.max_concurrency() > 1 {
            Isolation::Concurrent
        } else {
            Isolation::Strict
        };
        ServerApp {
            cfg,
            host,
            env_cfg: EnvConfig { isolation, ..Default::default() },
            strategy,
            scheduler,
            clients: clients.into_iter().map(Some).collect(),
            eval_data: None,
            workers: 1,
            executor_factory: None,
            trace: Trace::default(),
        }
    }

    pub fn with_eval_data(mut self, data: Dataset) -> Self {
        self.eval_data = Some(data);
        self
    }

    /// Run real fits on `workers` pool threads, each building its own
    /// executor via `factory`.  `workers = 1` keeps the in-thread engine.
    /// Emulated limits cannot stay globally exclusive once real fits
    /// overlap, so `workers > 1` forces `Isolation::Concurrent`.
    pub fn with_round_engine(
        mut self,
        workers: usize,
        factory: Option<ExecutorFactory>,
    ) -> Self {
        self.workers = workers.max(1);
        self.executor_factory = factory;
        if self.workers > 1 {
            self.env_cfg.isolation = Isolation::Concurrent;
        }
        self
    }

    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    /// Run the federation with a PJRT executor; returns the training
    /// history.  The executor initialises the global model and serves
    /// evaluation (and the sequential engine's fits).
    pub fn run(
        &mut self,
        executor: &mut ModelExecutor,
        clock: &mut VirtualClock,
    ) -> Result<(ParamVector, History), FlError> {
        let init = executor
            .init_params(self.cfg.seed as i32)
            .map_err(|e| FlError::Strategy(format!("init failed: {e}")))?;
        self.run_from(init, Some(executor), clock)
    }

    /// Run the federation from explicit initial parameters, with or
    /// without a PJRT executor.  Executor-less runs cover timing-only
    /// federations (`SimClient` fleets): fits, scheduling, aggregation and
    /// history all work; centralised evaluation is skipped.
    pub fn run_from(
        &mut self,
        init: ParamVector,
        mut executor: Option<&mut ModelExecutor>,
        clock: &mut VirtualClock,
    ) -> Result<(ParamVector, History), FlError> {
        if self.clients.is_empty() {
            return Err(FlError::NoClients { round: 0 });
        }
        let mut global = init;
        let mut history = History::default();
        let mut manager = ClientManager::new(self.cfg.seed, self.cfg.selection);
        let pool = if self.workers > 1 {
            Some(WorkerPool::spawn(self.workers, self.executor_factory.clone()))
        } else {
            None
        };

        for round in 0..self.cfg.rounds {
            let host_t0 = Instant::now();
            let selected = manager.select(self.clients.len());
            let fit_cfg = self.strategy.configure(round, &self.cfg.fit);

            // --- fit phase: stream completions into the accumulator ------
            let mut ledger =
                RoundLedger::new(selected.iter().map(|&i| i as u32).collect());
            let mut acc = self.strategy.accumulator(global.len(), selected.len());
            let round_t0 = clock.now_s();
            match &pool {
                Some(pool) => self.round_pooled(
                    pool, &selected, &global, &fit_cfg, clock, &mut ledger, &mut acc,
                )?,
                None => self.round_inline(
                    &mut executor, &selected, &global, &fit_cfg, clock, &mut ledger,
                    &mut acc,
                )?,
            }

            if ledger.successes() == 0 {
                if self.cfg.fail_on_empty_round {
                    return Err(FlError::AllClientsFailed {
                        round,
                        count: selected.len(),
                    });
                }
                let selected = std::mem::take(&mut ledger.selected);
                let failures = std::mem::take(&mut ledger.failures);
                history.push(RoundRecord {
                    round,
                    selected,
                    failures,
                    train_loss: f32::NAN,
                    eval_loss: None,
                    eval_accuracy: None,
                    emu_round_s: 0.0,
                    host_round_s: host_t0.elapsed().as_secs_f64(),
                });
                continue;
            }

            // --- round wall-clock per the scheduling policy --------------
            let schedule = self.scheduler.schedule(&ledger.durations);
            let base = round_t0;
            for &(c, s, e) in &schedule.spans {
                self.trace.add(c, format!("round{round}"), base + s, base + e);
            }

            // --- aggregate ------------------------------------------------
            let output = acc.finish()?;
            global = self
                .strategy
                .reduce(&global, output, executor.as_deref_mut())?;

            // --- evaluate -------------------------------------------------
            let (eval_loss, eval_accuracy) = if self.cfg.eval_every > 0
                && (round + 1) % self.cfg.eval_every == 0
            {
                match executor
                    .as_deref_mut()
                    .and_then(|ex| self.evaluate(ex, &global))
                {
                    Some((l, a)) => (Some(l), Some(a)),
                    None => (None, None),
                }
            } else {
                (None, None)
            };

            let train_loss = ledger.train_loss();
            let selected = std::mem::take(&mut ledger.selected);
            let failures = std::mem::take(&mut ledger.failures);
            history.push(RoundRecord {
                round,
                selected,
                failures,
                train_loss,
                eval_loss,
                eval_accuracy,
                emu_round_s: schedule.round_s,
                host_round_s: host_t0.elapsed().as_secs_f64(),
            });
        }
        Ok((global, history))
    }

    /// The paper-default engine: fits run sequentially in this thread,
    /// each finished client folded into the accumulator immediately.
    #[allow(clippy::too_many_arguments)]
    fn round_inline(
        &mut self,
        executor: &mut Option<&mut ModelExecutor>,
        selected: &[usize],
        global: &ParamVector,
        fit_cfg: &FitConfig,
        clock: &mut VirtualClock,
        ledger: &mut RoundLedger,
        acc: &mut Box<dyn AggAccumulator>,
    ) -> Result<(), FlError> {
        for &ci in selected {
            let client = self.clients[ci].as_mut().expect("client checked in");
            let mut ctx = BouquetContext {
                executor: executor.as_deref_mut(),
                clock,
                host: &self.host,
                env_cfg: self.env_cfg.clone(),
            };
            match client.fit(global, fit_cfg, &mut ctx) {
                Ok(result) => fold(ledger, acc, result)?,
                Err(e @ EmuError::GpuOom { .. }) | Err(e @ EmuError::HostOom { .. }) => {
                    // The paper's OOM story: the framework survives a
                    // failing client; it simply contributes no update.
                    ledger.record_failure(client.id(), e.to_string());
                }
                Err(other) => {
                    return Err(FlError::ClientFailed {
                        client: client.id(),
                        source: other,
                    })
                }
            }
        }
        Ok(())
    }

    /// The concurrent engine: fits run on the pool; outcomes stream back
    /// in completion order and pass through a reorder buffer so every fold
    /// (accumulator, ledger, shared clock) happens in selection order —
    /// bit-identical to the inline engine.
    #[allow(clippy::too_many_arguments)]
    fn round_pooled(
        &mut self,
        pool: &WorkerPool,
        selected: &[usize],
        global: &ParamVector,
        fit_cfg: &FitConfig,
        clock: &mut VirtualClock,
        ledger: &mut RoundLedger,
        acc: &mut Box<dyn AggAccumulator>,
    ) -> Result<(), FlError> {
        let shared = Arc::new(global.clone());
        for (pos, &ci) in selected.iter().enumerate() {
            let client = self.clients[ci].take().expect("client checked in");
            pool.submit(FitTask {
                index: pos,
                client,
                global: Arc::clone(&shared),
                cfg: fit_cfg.clone(),
                host: self.host.clone(),
                env_cfg: self.env_cfg.clone(),
            })?;
        }

        let mut reorder = ReorderBuffer::new(selected.len());
        let mut fatal: Option<FlError> = None;
        for _ in 0..selected.len() {
            let outcome = pool.recv()?;
            self.clients[selected[outcome.index]] = Some(outcome.client);
            reorder.accept(FitOutcomeSlim {
                index: outcome.index,
                client_id: outcome.client_id,
                result: outcome.result,
            });
            while let Some(slim) = reorder.pop_ready() {
                // Once the round is doomed, keep draining (every client must
                // come back) but stop folding — the first error is the one
                // the caller sees.
                if fatal.is_some() {
                    continue;
                }
                match slim.result {
                    Ok(result) => {
                        // Replay the emulated time the inline engine would
                        // have advanced during this fit, increment for
                        // increment (bit-identical clock trajectory).
                        clock.advance(result.emu.warmup_s);
                        for _ in 0..result.emu.steps {
                            clock.advance(result.emu.step_s);
                        }
                        if let Err(e) = fold(ledger, acc, result) {
                            fatal = Some(e);
                        }
                    }
                    Err(e @ EmuError::GpuOom { .. })
                    | Err(e @ EmuError::HostOom { .. }) => {
                        ledger.record_failure(slim.client_id, e.to_string());
                    }
                    Err(other) => {
                        fatal = Some(FlError::ClientFailed {
                            client: slim.client_id,
                            source: other,
                        });
                    }
                }
            }
        }
        // All clients are checked back in; only now surface a fatal error
        // (same observable as the inline engine's early return).
        match fatal {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Centralised eval over the held-out set (batched by the compiled
    /// eval artifact's batch size; a trailing partial batch is padded by
    /// wrapping, standard practice for fixed-shape accelerator eval).
    fn evaluate(
        &self,
        executor: &mut ModelExecutor,
        global: &ParamVector,
    ) -> Option<(f32, f32)> {
        let data = self.eval_data.as_ref()?;
        let batch = executor.eval_batch_size()?;
        let n = data.len();
        if n == 0 {
            return None;
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut seen = 0usize;
        let mut start = 0usize;
        while seen < n {
            let idx: Vec<usize> = (0..batch as usize).map(|i| (start + i) % n).collect();
            let (x, y) = data.gather(&idx);
            let take = (batch as usize).min(n - seen);
            match executor.eval_batch(global, &x, &y, batch) {
                Ok((l, c)) => {
                    // Only count the non-wrapped fraction.
                    let frac = take as f64 / batch as f64;
                    loss_sum += l as f64 * take as f64;
                    correct += c as f64 * frac;
                }
                Err(_) => return None,
            }
            seen += take;
            start += take;
        }
        Some(((loss_sum / n as f64) as f32, (correct / n as f64) as f32))
    }
}

/// Fold one success into the round's scalar ledger and the streaming
/// aggregate; the `FitResult` (and its param vector) dies here.
fn fold(
    ledger: &mut RoundLedger,
    acc: &mut Box<dyn AggAccumulator>,
    result: FitResult,
) -> Result<(), FlError> {
    ledger.record_success(&result);
    acc.push(result)
}
