//! The federated server (Flower's `ServerApp` analogue): round loop,
//! client selection, BouquetFL-restricted fits, failure handling,
//! streaming aggregation, centralised evaluation, history.
//!
//! The round loop consumes a *completion stream* of fit outcomes instead
//! of collecting a `Vec<FitResult>`: each finished client is folded into
//! the strategy's [`AggAccumulator`] and dropped, so peak memory for the
//! mean-family strategies is O(params) regardless of federation size
//! (DESIGN.md §8).  With `with_round_engine(workers > 1, ..)` the fits
//! themselves run concurrently on a [`WorkerPool`]; a reorder buffer
//! restores selection order before folding, so the aggregate, the emulated
//! `Schedule`, and the shared clock are bit-identical to the sequential
//! engine.
//!
//! A [`Scenario`] (via [`ServerApp::with_scenario`]) layers federation
//! dynamics on top: per-round eligibility (membership churn + availability
//! traces), mid-round dropout, and deadline-closed rounds.  All dynamic
//! decisions run in selection order on values identical across worker
//! counts, so the bit-identity invariant extends to dynamic federations
//! (DESIGN.md §9, SCENARIOS.md).

use std::borrow::Cow;
use std::sync::Arc;
use std::time::Instant;

use crate::data::Dataset;
use crate::emu::{EnvConfig, Isolation, VirtualClock};
use crate::error::{EmuError, FlError};
use crate::hardware::profile::HardwareProfile;
use crate::net::NetworkProfile;
use crate::netsim::{NetSim, UNMODELED_LINK};
use crate::runtime::ModelExecutor;
use crate::sched::dynamics::{FederationDynamics, GateVerdict, RoundGate};
use crate::sched::pool::FitOutcomeSlim;
use crate::sched::{
    ExecutorFactory, FitTask, ReorderBuffer, Schedule, Scheduler, Trace, WorkerPool,
};

use crate::durable::{Checkpoint, EventLogObserver, RunDurability, CHECKPOINT_FILE};
use crate::obs::{Phase, PhaseGuard, PhaseRecorder};

use super::attack::Attack;
use super::bouquet::BouquetContext;
use super::client::{ClientApp, ClientId, FitConfig, FitResult};
use super::clientmgr::{ClientManager, RoundLedger, Selection};
use super::events::{
    CommDirection, FailureKind, FlEvent, FlObserver, HistoryObserver, TraceObserver,
};
use super::history::{History, RoundRecord};
use super::params::{ParamScratch, ParamVector};
use super::population::{ClientFactory, Population};
use super::scenario::Scenario;
use super::strategy::{AggAccumulator, FoldPlan, Strategy};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub rounds: u32,
    pub selection: Selection,
    pub fit: FitConfig,
    /// Run centralised evaluation every N rounds (0 = never).
    pub eval_every: u32,
    pub seed: u64,
    /// Abort if a round ends with zero surviving clients.  Under a dynamic
    /// scenario an empty round is an expected outcome (everyone dropped or
    /// missed the deadline), so this only applies to static federations.
    pub fail_on_empty_round: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            rounds: 10,
            selection: Selection::All,
            fit: FitConfig::default(),
            eval_every: 5,
            seed: 42,
            fail_on_empty_round: true,
        }
    }
}

/// The federation roster the round loop checks clients out of: either a
/// materialised fleet of live objects (the historical layout) or a
/// descriptor-backed [`Population`] that instantiates clients per round
/// through a [`ClientFactory`] (DESIGN.md §11).  Checkout/checkin is the
/// one seam the engine needs — everything downstream of it (fits, gating,
/// folding) is layout-agnostic.
enum Roster {
    /// Live clients; `None` marks one currently checked out to a worker.
    Materialized(Vec<Option<Box<dyn ClientApp>>>),
    /// Compact descriptors; clients exist only while a round runs them.
    Population {
        population: Population,
        factory: Box<dyn ClientFactory>,
    },
}

impl Roster {
    fn len(&self) -> usize {
        match self {
            Roster::Materialized(v) => v.len(),
            Roster::Population { population, .. } => population.len(),
        }
    }

    /// Take client `idx` out for one fit: the live object for a
    /// materialised fleet, a factory instantiation for a population.
    fn checkout(&mut self, idx: usize) -> Box<dyn ClientApp> {
        match self {
            Roster::Materialized(v) => v[idx].take().expect("client checked in"),
            Roster::Population { population, factory } => {
                let desc = population.descriptor(idx);
                factory.instantiate(idx as ClientId, &desc, population.profile(desc.profile))
            }
        }
    }

    /// Hand client `idx` back after its fit.  For a population the
    /// descriptor *is* the checked-in form — the live object is dropped
    /// (clients are stateless across rounds by construction, asserted by
    /// the materialised-vs-population bit-identity property).
    fn checkin(&mut self, idx: usize, client: Box<dyn ClientApp>) {
        match self {
            Roster::Materialized(v) => v[idx] = Some(client),
            Roster::Population { .. } => drop(client),
        }
    }

    /// Client `idx`'s network link, when one is attached — the netsim
    /// layer's per-client input.  O(1) for both layouts (a population
    /// derives the descriptor on demand).
    fn network_of(&self, idx: usize) -> Option<NetworkProfile> {
        match self {
            Roster::Materialized(v) => {
                v[idx].as_ref().and_then(|c| c.network().copied())
            }
            Roster::Population { population, .. } => {
                population.descriptor(idx).network_profile()
            }
        }
    }
}

/// Per-round state of the communication simulator (DESIGN.md §12), only
/// materialised when netsim is enabled: the selected cohort's links, the
/// download timeline (computable at round start — it depends only on who
/// was selected), and the round's successful fits buffered in selection
/// order until the upload timeline can be solved.  Everything here is
/// O(cohort) — at population scale that is the engine's only
/// netsim-specific state.
///
/// Buffering is what contention costs: upload completion times depend on
/// *every* arrival, so gating/folding must wait for the whole cohort
/// (Krum and trimmed-mean already buffer the cohort's updates by nature;
/// netsim extends that bound to every strategy for netsim runs, and the
/// recycled-scratch path keeps the buffers allocation-free in steady
/// state).
struct NetsimRound {
    /// Selected clients' links, by selection position.
    links: Vec<NetworkProfile>,
    /// Download completion per selection position (round-relative).
    download_s: Vec<f64>,
    /// Successful fits awaiting the upload timeline, in selection order:
    /// (selection position, result).
    buffered: Vec<(usize, FitResult)>,
}

impl NetsimRound {
    /// Solve the download phase for the selected cohort: every client
    /// starts fetching the model at round-relative t = 0, sharing the
    /// server's egress capacity.
    fn begin(netsim: &NetSim, links: Vec<NetworkProfile>) -> NetsimRound {
        let download_s = netsim.download_finish(&links);
        NetsimRound { links, download_s, buffered: Vec::new() }
    }
}

/// The federated server.
pub struct ServerApp {
    pub cfg: ServerConfig,
    pub host: HardwareProfile,
    pub env_cfg: EnvConfig,
    strategy: Box<dyn Strategy>,
    scheduler: Box<dyn Scheduler>,
    roster: Roster,
    /// Held-out evaluation data (centralised, on the server).
    eval_data: Option<Dataset>,
    /// Real-execution concurrency (1 = in-thread sequential fits).
    workers: usize,
    /// Per-worker executor builder for the concurrent engine.
    executor_factory: Option<ExecutorFactory>,
    /// Federation dynamics (availability/churn/dropout/deadline); `None`
    /// runs the static engine exactly as before.
    dynamics: Option<FederationDynamics>,
    /// A scenario attached via [`ServerApp::with_scenario`], compiled into
    /// `dynamics` lazily at the first `run_from` — so the slot count always
    /// reflects the *final* scheduler, whatever order the `with_*` calls
    /// came in.
    scenario: Option<Scenario>,
    /// Contention-aware communication simulator (DESIGN.md §12); `None`
    /// keeps the closed-form `round_comm_s` fast path bit-identical to
    /// the pre-netsim engine.
    netsim: Option<NetSim>,
    /// Seeded adversarial-client model (DESIGN.md §13); `None` keeps the
    /// engine bit-identical to the unattacked code path.
    attack: Option<Attack>,
    /// User subscribers to the typed event stream (`fl::events`).
    observers: Vec<Box<dyn FlObserver>>,
    /// Recycled parameter buffers shared by client fits and the
    /// aggregation accumulator (EXPERIMENTS.md §Perf).
    scratch: ParamScratch,
    /// Reduction topology for the mean family (DESIGN.md §16).  `Serial`
    /// (the default) is the historical left fold, byte-for-byte; `Tree`
    /// shards the fold across fixed selection-index leaves so pool
    /// workers can fold their own completions.
    fold_plan: FoldPlan,
    /// Durable-run harness (DESIGN.md §14): event-log writer, checkpoint
    /// cadence, and — on resume — the restored state to continue from.
    /// Consumed by the next run (one run per attachment).
    durable: Option<RunDurability>,
    /// Host-domain phase timer (DESIGN.md §17); `None` keeps the round
    /// loop free of wall-clock reads beyond `host_round_s`.
    phase_recorder: Option<PhaseRecorder>,
    pub trace: Trace,
}

impl ServerApp {
    pub fn new(
        cfg: ServerConfig,
        host: HardwareProfile,
        strategy: Box<dyn Strategy>,
        scheduler: Box<dyn Scheduler>,
        clients: Vec<Box<dyn ClientApp>>,
    ) -> Self {
        Self::with_roster(
            cfg,
            host,
            strategy,
            scheduler,
            Roster::Materialized(clients.into_iter().map(Some).collect()),
        )
    }

    /// Build a server over a descriptor-backed [`Population`]: clients
    /// exist as compact descriptors and are instantiated through
    /// `factory` only for the rounds that select them, so a
    /// million-client federation with `Selection::Count(64)` runs in
    /// memory proportional to the cohort and the profile table, never the
    /// population (DESIGN.md §11).
    pub fn with_population(
        cfg: ServerConfig,
        host: HardwareProfile,
        strategy: Box<dyn Strategy>,
        scheduler: Box<dyn Scheduler>,
        population: Population,
        factory: Box<dyn ClientFactory>,
    ) -> Self {
        Self::with_roster(
            cfg,
            host,
            strategy,
            scheduler,
            Roster::Population { population, factory },
        )
    }

    fn with_roster(
        cfg: ServerConfig,
        host: HardwareProfile,
        strategy: Box<dyn Strategy>,
        scheduler: Box<dyn Scheduler>,
        roster: Roster,
    ) -> Self {
        // The paper's §3: hardware controls are global; only the
        // limited-parallel extension may relax isolation.
        let isolation = if scheduler.max_concurrency() > 1 {
            Isolation::Concurrent
        } else {
            Isolation::Strict
        };
        ServerApp {
            cfg,
            host,
            env_cfg: EnvConfig { isolation, ..Default::default() },
            strategy,
            scheduler,
            roster,
            eval_data: None,
            workers: 1,
            executor_factory: None,
            dynamics: None,
            scenario: None,
            netsim: None,
            attack: None,
            observers: Vec::new(),
            scratch: ParamScratch::default(),
            fold_plan: FoldPlan::default(),
            durable: None,
            phase_recorder: None,
            trace: Trace::default(),
        }
    }

    pub fn with_eval_data(mut self, data: Dataset) -> Self {
        self.eval_data = Some(data);
        self
    }

    /// Run real fits on `workers` pool threads, each building its own
    /// executor via `factory`.  `workers = 1` keeps the in-thread engine.
    /// Emulated limits cannot stay globally exclusive once real fits
    /// overlap, so `workers > 1` forces `Isolation::Concurrent`.
    pub fn with_round_engine(
        mut self,
        workers: usize,
        factory: Option<ExecutorFactory>,
    ) -> Self {
        self.workers = workers.max(1);
        self.executor_factory = factory;
        if self.workers > 1 {
            self.env_cfg.isolation = Isolation::Concurrent;
        }
        self
    }

    /// Attach a federation-dynamics scenario (SCENARIOS.md).  A static
    /// scenario (the `stable` preset) compiles to nothing, so the engine
    /// output stays bit-identical to a scenario-less run.
    ///
    /// The scenario is compiled into runtime dynamics at the first
    /// `run_from`, **not** here — the dynamics slot count must reflect the
    /// scheduler the run actually uses, so `with_scenario` /
    /// `with_scheduler` / `with_round_engine` may be chained in any order.
    pub fn with_scenario(mut self, scenario: &Scenario) -> Self {
        self.dynamics = None;
        self.scenario = if scenario.is_static() { None } else { Some(scenario.clone()) };
        self
    }

    /// Attach pre-built dynamics directly (custom/hand-crafted traces).
    /// Overrides any pending [`ServerApp::with_scenario`].
    pub fn with_dynamics(mut self, dynamics: FederationDynamics) -> Self {
        self.scenario = None;
        self.dynamics = Some(dynamics);
        self
    }

    /// Replace the emulated-timeline scheduler.  Isolation follows the
    /// paper's rule: anything that lets restricted environments overlap
    /// (an emulated slot count above 1, or real pool workers) forces
    /// [`Isolation::Concurrent`].
    pub fn with_scheduler(mut self, scheduler: Box<dyn Scheduler>) -> Self {
        self.scheduler = scheduler;
        self.env_cfg.isolation = if self.scheduler.max_concurrency() > 1 || self.workers > 1 {
            Isolation::Concurrent
        } else {
            Isolation::Strict
        };
        self
    }

    /// Attach the contention-aware communication simulator (DESIGN.md
    /// §12): per-round transfers share the server's finite
    /// ingress/egress capacity under max-min fair share, and each kept
    /// update is charged (bytes and accuracy) through the configured
    /// codec.  The simulated timeline *replaces* both the clients'
    /// closed-form `round_comm_s` and the configured emulated scheduler's
    /// round clock — under netsim every client downloads, fits and
    /// uploads concurrently, contending on the shared pipes rather than
    /// on emulated compute slots.  Without this call the engine is
    /// bit-identical to the pre-netsim code path.
    pub fn with_netsim(mut self, netsim: NetSim) -> Self {
        self.netsim = Some(netsim);
        self
    }

    /// Attach a seeded adversarial-client model (DESIGN.md §13):
    /// membership is a pure function of `(seed, client)`, and each
    /// compromised client's kept update is perturbed at the aggregation
    /// seam — after the netsim codec decodes it, immediately before the
    /// accumulator fold.  With `fraction = 0` (or without this call) the
    /// engine is bit-identical to the unattacked code path.
    pub fn with_attack(mut self, attack: Attack) -> Self {
        self.attack = Some(attack);
        self
    }

    /// Select the mean-family reduction topology (DESIGN.md §16).
    /// [`FoldPlan::Serial`] (the default) keeps the historical
    /// selection-order left fold bit-for-bit.  [`FoldPlan::Tree`] merges
    /// fixed selection-index leaves in binary-tree order — bit-identical
    /// across `--workers {1,2,4,8}` and across durable resume, within
    /// 1e-6 of the serial fold (property-tested), and lets pool workers
    /// fold their own completions on gate/netsim/attack-free rounds.
    /// Robust (buffering) strategies ignore the plan.
    pub fn with_fold_plan(mut self, plan: FoldPlan) -> Self {
        self.fold_plan = plan;
        self
    }

    /// Subscribe an observer to the typed event stream (`fl::events`).
    /// Observers run in attach order after the built-in history/trace
    /// subscribers.
    pub fn with_observer(mut self, observer: Box<dyn FlObserver>) -> Self {
        self.observers.push(observer);
        self
    }

    /// Attach a host-domain phase timer (DESIGN.md §17): the round loop's
    /// select → dispatch → fit → comm → gate → fold → eval → checkpoint
    /// phases are timed on the wall clock into the recorder's hub (and
    /// its span list).  Host telemetry only — attaching one changes no
    /// event, aggregate, or simulated-domain metric.
    pub fn with_phase_recorder(mut self, recorder: PhaseRecorder) -> Self {
        self.phase_recorder = Some(recorder);
        self
    }

    /// Attach durable-run infrastructure (DESIGN.md §14): every event the
    /// round loop emits is appended to a CRC-framed log, and the server's
    /// cross-round state is checkpointed at the harness's cadence.  The
    /// attachment is consumed by the next run — one run per attachment.
    pub fn with_durable(mut self, durability: RunDurability) -> Self {
        self.durable = Some(durability);
        self
    }

    /// Resume a durable run from its directory: loads the checkpoint,
    /// truncates the event log to the checkpointed offset, and arranges
    /// for the next run to continue — bit-identically — from the first
    /// unfinished round.
    pub fn resume_from(self, dir: impl AsRef<std::path::Path>) -> Result<Self, FlError> {
        let dir = dir.as_ref();
        let durability = RunDurability::resume(dir)
            .map_err(|e| FlError::Durable(format!("{}: {e}", dir.display())))?;
        Ok(self.with_durable(durability))
    }

    pub fn num_clients(&self) -> usize {
        self.roster.len()
    }

    /// Run the federation with a PJRT executor; returns the training
    /// history.  The executor initialises the global model and serves
    /// evaluation (and the sequential engine's fits).
    pub fn run(
        &mut self,
        executor: &mut ModelExecutor,
        clock: &mut VirtualClock,
    ) -> Result<(ParamVector, History), FlError> {
        let init = executor
            .init_params(self.cfg.seed as i32)
            .map_err(|e| FlError::Strategy(format!("init failed: {e}")))?;
        self.run_from(init, Some(executor), clock)
    }

    /// Run the federation from explicit initial parameters, with or
    /// without a PJRT executor.  Executor-less runs cover timing-only
    /// federations (`SimClient` fleets): fits, scheduling, aggregation and
    /// history all work; centralised evaluation is skipped.
    pub fn run_from(
        &mut self,
        init: ParamVector,
        executor: Option<&mut ModelExecutor>,
        clock: &mut VirtualClock,
    ) -> Result<(ParamVector, History), FlError> {
        // History and the emulated-timeline trace are event subscribers
        // like any other — the round loop only emits `FlEvent`s.  The
        // trace merges back onto the public field on every exit path, so
        // a failed run keeps the spans of its completed rounds.
        let mut recorder = HistoryObserver::default();
        let mut tracer = TraceObserver::default();
        let result = self.run_rounds(init, executor, clock, &mut recorder, &mut tracer);
        self.trace.events.extend(tracer.into_trace().events);
        result.map(|global| (global, recorder.into_history()))
    }

    /// The round loop behind [`ServerApp::run_from`]: emits the event
    /// stream to the built-in subscribers and every attached observer.
    fn run_rounds(
        &mut self,
        init: ParamVector,
        mut executor: Option<&mut ModelExecutor>,
        clock: &mut VirtualClock,
        recorder: &mut HistoryObserver,
        tracer: &mut TraceObserver,
    ) -> Result<ParamVector, FlError> {
        let roster_len = self.roster.len();
        if roster_len == 0 {
            return Err(FlError::NoClients { round: 0 });
        }
        // Compile a pending scenario now — against the *final* scheduler's
        // slot count and the final roster size — so the `with_*` chain is
        // order-insensitive (the `with_scenario`-before-`with_scheduler`
        // footgun is resolved here, not at call time).
        if self.dynamics.is_none() {
            if let Some(sc) = &self.scenario {
                self.dynamics = Some(sc.build_dynamics(
                    self.cfg.seed,
                    roster_len,
                    self.scheduler.max_concurrency(),
                ));
            }
        }
        let mut global = init;
        let mut manager = ClientManager::new(self.cfg.seed, self.cfg.selection);

        // --- durable runs (DESIGN.md §14) --------------------------------
        // Take the harness out of `self` so checkpointing can borrow the
        // server's disjoint pieces (strategy, attack, dynamics) freely.
        // On resume: restore every piece of cross-round state from the
        // checkpoint, replay the log's clean prefix through the observers
        // (so history/trace/user subscribers see the completed rounds),
        // and only then subscribe the log writer — replayed events must
        // not be re-appended.
        let mut durable = self.durable.take();
        // Host-domain phase timer (DESIGN.md §17), taken like the durable
        // harness so guards never borrow `self` across the loop's mutable
        // uses.  `None` compiles every `pstart` below to nothing.
        let phases = self.phase_recorder.take();
        let start_round = match durable.as_mut().and_then(|d| d.take_resume()) {
            Some(ckpt) => {
                if ckpt.global.len() != global.len() {
                    return Err(FlError::Durable(format!(
                        "checkpoint holds {} params but the model has {}",
                        ckpt.global.len(),
                        global.len()
                    )));
                }
                global = ParamVector::from_vec(ckpt.global);
                *clock = VirtualClock::resume_at(ckpt.clock_s, clock.mode());
                manager.restore_rng(ckpt.manager_rng.0, ckpt.manager_rng.1);
                self.strategy.restore_state(&ckpt.strategy_blob);
                if let Some(atk) = self.attack.as_mut() {
                    atk.restore_state(&ckpt.attack_blob);
                }
                if let Some((rounds_begun, now_s)) = ckpt.dynamics {
                    match self.dynamics.as_mut() {
                        Some(d) => d.restore_timeline(rounds_begun, now_s),
                        None => {
                            return Err(FlError::Durable(
                                "checkpoint carries dynamics state but the server \
                                 has no scenario"
                                    .into(),
                            ))
                        }
                    }
                }
                ckpt.next_round
            }
            None => 0,
        };
        if let Some(d) = durable.as_mut() {
            for owned in d.take_prefix() {
                if let Some(event) = owned.as_event() {
                    recorder.on_event(&event);
                    tracer.on_event(&event);
                    for observer in self.observers.iter_mut() {
                        observer.on_event(&event);
                    }
                }
            }
            self.observers.push(Box::new(EventLogObserver::new(d.writer())));
        }

        let pool = if self.workers > 1 {
            Some(WorkerPool::spawn_scratched(
                self.workers,
                self.executor_factory.clone(),
                self.scratch.clone(),
            ))
        } else {
            None
        };
        if start_round == 0 {
            notify(
                recorder,
                tracer,
                &mut self.observers,
                FlEvent::RunBegin { rounds: self.cfg.rounds, clients: roster_len },
            );
        }

        for round in start_round..self.cfg.rounds {
            // detlint: allow(R2) — host-side round duration is diagnostic telemetry (host_round_s); it never feeds the simulated clock or aggregates
            let host_t0 = Instant::now();

            // --- dynamics: churn + eligibility ---------------------------
            let select_span = pstart(&phases, Phase::Select);
            if let Some(d) = self.dynamics.as_mut() {
                d.begin_round();
            }
            let cohort: Cow<'_, [usize]> = match self.dynamics.as_mut() {
                Some(d) => {
                    // Availability is judged on the scenario timeline (the
                    // sum of recorded round lengths), which is identical
                    // across worker counts and consistent with the history.
                    let now = d.now_s();
                    // Below the dense threshold the materialised-era pool
                    // sweep (and its RNG stream) is kept bit-identical;
                    // above it, eligibility is evaluated lazily for
                    // sampled candidates only — no O(population) work per
                    // round (DESIGN.md §11).
                    let sel = if d.is_lazy() {
                        manager.select_filtered(roster_len, &mut |i| d.is_eligible(i, now))
                    } else {
                        let eligible = d.eligible_at(now);
                        if eligible.is_empty() {
                            Vec::new()
                        } else {
                            manager.select_from(&eligible)
                        }
                    };
                    if sel.is_empty() {
                        // Nobody is online: fast-forward to the next member
                        // coming back (otherwise the timeline would never
                        // move and every later round would see the same
                        // offline federation), record a skipped round, and
                        // move on.  The shared clock advances too so
                        // real-time pacing observes the wait.
                        let wait = match d.next_wakeup_after(now) {
                            Some(t) => {
                                let w = (t - now).max(0.0);
                                d.advance(w);
                                clock.advance(w);
                                w
                            }
                            None => 0.0,
                        };
                        let record = RoundRecord {
                            round,
                            selected: Vec::new(),
                            failures: Vec::new(),
                            train_loss: f32::NAN,
                            eval_loss: None,
                            eval_accuracy: None,
                            emu_round_s: wait,
                            host_round_s: host_t0.elapsed().as_secs_f64(),
                        };
                        notify(
                            recorder,
                            tracer,
                            &mut self.observers,
                            FlEvent::RoundSkipped { round, wait_s: wait },
                        );
                        notify_round_end(recorder, tracer, &mut self.observers, record);
                        let _ckpt_span =
                            if durable.is_some() { pstart(&phases, Phase::Checkpoint) } else { None };
                        durable_round_boundary(
                            durable.as_ref(),
                            Some(&*d),
                            &*self.strategy,
                            self.attack.as_ref(),
                            self.cfg.rounds,
                            round,
                            &global,
                            &manager,
                            clock,
                        )?;
                        continue;
                    }
                    Cow::Owned(sel)
                }
                // Static federations borrow the manager's cached pool /
                // scratch cohort — no per-round selection allocation.
                None => Cow::Borrowed(manager.select(roster_len)),
            };
            let selected: &[usize] = cohort.as_ref();
            drop(select_span);
            let fit_cfg = self.strategy.configure(round, &self.cfg.fit);
            notify(
                recorder,
                tracer,
                &mut self.observers,
                FlEvent::RoundBegin { round, selected },
            );
            // Arm the attack for this round: snapshot the pre-round global
            // (models perturb relative to it) and clear the injected list.
            if let Some(atk) = self.attack.as_mut() {
                atk.begin_round(round, global.as_slice());
            }

            // --- fit phase: stream completions into the accumulator ------
            let mut ledger =
                RoundLedger::new(selected.iter().map(|&i| i as u32).collect());
            let mut acc = self.strategy.accumulator_planned(
                global.len(),
                selected.len(),
                &self.scratch,
                self.fold_plan,
            );
            // Netsim: the download phase is solvable at round start (it
            // depends only on who was selected); fits are then buffered in
            // selection order until the upload timeline can be solved.
            let mut netsim_round = self.netsim.as_ref().map(|ns| {
                let links: Vec<NetworkProfile> = selected
                    .iter()
                    .map(|&i| self.roster.network_of(i).unwrap_or(UNMODELED_LINK))
                    .collect();
                NetsimRound::begin(ns, links)
            });
            let round_t0 = clock.now_s();
            let mut gate = self.dynamics.as_ref().map(|d| d.begin_gate(d.now_s()));
            let mut dyn_gate = self.dynamics.as_mut().zip(gate.as_mut());
            let fit_span = pstart(&phases, Phase::Fit);
            match &pool {
                Some(pool) => round_pooled(
                    &mut self.roster,
                    &self.host,
                    &self.env_cfg,
                    pool,
                    selected,
                    &global,
                    &fit_cfg,
                    clock,
                    &mut ledger,
                    &mut acc,
                    &mut dyn_gate,
                    &mut netsim_round,
                    &mut self.attack,
                    phases.as_ref(),
                )?,
                None => round_inline(
                    &mut self.roster,
                    &self.host,
                    &self.env_cfg,
                    &mut executor,
                    selected,
                    &global,
                    &fit_cfg,
                    clock,
                    &mut ledger,
                    &mut acc,
                    &mut dyn_gate,
                    &mut netsim_round,
                    &mut self.attack,
                    &self.scratch,
                )?,
            }
            drop(fit_span);

            // --- netsim: solve the upload timeline, gate and fold --------
            // With netsim on, per-client comm windows come from the shared
            // fair-share timeline instead of the closed form; the round's
            // schedule is that timeline's kept spans.  Built AFTER every
            // fit of the round is in (upload completion depends on every
            // arrival), from selection-order data only — identical across
            // worker counts.
            let netsim_schedule = match netsim_round.take() {
                Some(nr) => Some(self.finish_netsim_round(
                    nr,
                    round,
                    selected,
                    &mut ledger,
                    &mut acc,
                    &mut gate,
                    recorder,
                    tracer,
                    phases.as_ref(),
                )?),
                None => None,
            };

            // Per-client events, interleaved back into true selection
            // order.  Successes and failures are each recorded in
            // selection order (the reorder buffer guarantees fold order on
            // any engine) and partition the selected roster, so a
            // two-pointer merge over it restores the full sequence.
            let (mut di, mut fi) = (0usize, 0usize);
            for &id in &ledger.selected {
                if di < ledger.durations.len() && ledger.durations[di].0 == id {
                    let fit_s = ledger.durations[di].1;
                    di += 1;
                    notify(
                        recorder,
                        tracer,
                        &mut self.observers,
                        FlEvent::ClientDone { round, client: id, fit_s },
                    );
                } else if fi < ledger.failures.len() && ledger.failures[fi].client == id {
                    let reason = &ledger.failures[fi].reason;
                    notify(
                        recorder,
                        tracer,
                        &mut self.observers,
                        FlEvent::ClientFailed {
                            round,
                            client: id,
                            kind: FailureKind::classify(reason),
                            reason,
                        },
                    );
                    fi += 1;
                }
            }
            debug_assert!(
                di == ledger.durations.len() && fi == ledger.failures.len(),
                "per-client event merge skipped entries: the selection-order \
                 invariant on ledger.durations/failures was violated"
            );

            // Compromised-client classification: one `AttackInjected` per
            // perturbed update, in fold (= selection) order.
            if let Some(atk) = self.attack.as_ref() {
                let model = atk.model_name();
                let injected: Vec<u32> = atk.injected().to_vec();
                for client in injected {
                    notify(
                        recorder,
                        tracer,
                        &mut self.observers,
                        FlEvent::AttackInjected { round, client, model },
                    );
                }
            }

            if ledger.successes() == 0 {
                // An empty round the *gate* caused (dropouts/deadline) is
                // an expected dynamics outcome; an empty round with no
                // gate drops (e.g. every client OOM'd) is the same failure
                // it would be on the static engine.
                let (dynamic_empty, empty_round_s) = match gate.as_ref() {
                    // An all-dropped round with lates held the round open
                    // until the deadline; a pure-dropout round lasted until
                    // the last observed disconnection (strictly positive,
                    // so the scenario timeline always moves and the round
                    // cannot replay identically forever).
                    Some(g) if g.dropped() > 0 => {
                        let len = if g.late() > 0 {
                            g.deadline_s()
                        } else {
                            g.dropout_horizon_s().min(g.deadline_s())
                        };
                        (true, len)
                    }
                    _ => (false, 0.0),
                };
                if self.cfg.fail_on_empty_round && !dynamic_empty {
                    return Err(FlError::AllClientsFailed {
                        round,
                        count: selected.len(),
                    });
                }
                if let Some(d) = self.dynamics.as_mut() {
                    d.advance(empty_round_s);
                }
                let selected = std::mem::take(&mut ledger.selected);
                let failures = std::mem::take(&mut ledger.failures);
                let record = RoundRecord {
                    round,
                    selected,
                    failures,
                    train_loss: f32::NAN,
                    eval_loss: None,
                    eval_accuracy: None,
                    emu_round_s: empty_round_s,
                    host_round_s: host_t0.elapsed().as_secs_f64(),
                };
                notify_round_end(recorder, tracer, &mut self.observers, record);
                let _ckpt_span =
                    if durable.is_some() { pstart(&phases, Phase::Checkpoint) } else { None };
                durable_round_boundary(
                    durable.as_ref(),
                    self.dynamics.as_ref(),
                    &*self.strategy,
                    self.attack.as_ref(),
                    self.cfg.rounds,
                    round,
                    &global,
                    &manager,
                    clock,
                )?;
                continue;
            }

            // --- round wall-clock per the scheduling policy --------------
            // A netsim round renders the simulated communication timeline
            // (already gate-aware).  Otherwise: a round the gate actually
            // touched renders the gate's own packing (the spans its drop
            // decisions were judged against); a drop-free round — and
            // every static round — renders the configured scheduler, so a
            // scenario that drops nobody is bit-identical to the static
            // engine for any scheduler.
            let schedule = match (netsim_schedule, gate.as_ref()) {
                (Some(s), _) => s,
                (None, Some(g)) if g.dropped() > 0 => g.schedule(),
                _ => self.scheduler.schedule(&ledger.durations),
            };
            if let Some(d) = self.dynamics.as_mut() {
                d.advance(schedule.round_s);
            }
            notify(
                recorder,
                tracer,
                &mut self.observers,
                FlEvent::RoundScheduled { round, base_s: round_t0, schedule: &schedule },
            );

            // --- aggregate ------------------------------------------------
            let fold_span = pstart(&phases, Phase::Fold);
            let output = acc.finish()?;
            global = self
                .strategy
                .reduce(&global, output, executor.as_deref_mut())?;
            drop(fold_span);
            notify(
                recorder,
                tracer,
                &mut self.observers,
                FlEvent::Aggregated { round, survivors: ledger.successes() },
            );
            // Adaptive attackers key off the (deterministic) event stream:
            // the engine feeds the model the aggregation and evaluation
            // signals it may condition the next round's perturbation on.
            if let Some(atk) = self.attack.as_mut() {
                atk.observe(&FlEvent::Aggregated {
                    round,
                    survivors: ledger.successes(),
                });
            }

            // --- evaluate -------------------------------------------------
            let (eval_loss, eval_accuracy) = if self.cfg.eval_every > 0
                && (round + 1) % self.cfg.eval_every == 0
            {
                let _eval_span = pstart(&phases, Phase::Eval);
                match executor
                    .as_deref_mut()
                    .and_then(|ex| self.evaluate(ex, &global))
                {
                    Some((l, a)) => {
                        notify(
                            recorder,
                            tracer,
                            &mut self.observers,
                            FlEvent::Evaluated { round, loss: l, accuracy: a },
                        );
                        if let Some(atk) = self.attack.as_mut() {
                            atk.observe(&FlEvent::Evaluated {
                                round,
                                loss: l,
                                accuracy: a,
                            });
                        }
                        (Some(l), Some(a))
                    }
                    None => (None, None),
                }
            } else {
                (None, None)
            };

            let train_loss = ledger.train_loss();
            let selected = std::mem::take(&mut ledger.selected);
            let failures = std::mem::take(&mut ledger.failures);
            let record = RoundRecord {
                round,
                selected,
                failures,
                train_loss,
                eval_loss,
                eval_accuracy,
                emu_round_s: schedule.round_s,
                host_round_s: host_t0.elapsed().as_secs_f64(),
            };
            notify_round_end(recorder, tracer, &mut self.observers, record);
            let _ckpt_span =
                if durable.is_some() { pstart(&phases, Phase::Checkpoint) } else { None };
            durable_round_boundary(
                durable.as_ref(),
                self.dynamics.as_ref(),
                &*self.strategy,
                self.attack.as_ref(),
                self.cfg.rounds,
                round,
                &global,
                &manager,
                clock,
            )?;
        }
        notify(
            recorder,
            tracer,
            &mut self.observers,
            FlEvent::RunEnd { rounds: self.cfg.rounds },
        );
        if let Some(d) = durable.as_ref() {
            let _ = d.lock_writer().sync();
        }
        Ok(global)
    }

    /// Close a netsim round (DESIGN.md §12): solve the upload timeline
    /// over every buffered fit, emit the transfer events, gate each
    /// client on its simulated `[0, upload end)` window, fold the kept
    /// (codec-compressed) updates, and return the round's schedule —
    /// the simulated timeline's kept spans.
    ///
    /// Runs entirely on selection-order data assembled by the reorder
    /// buffer, so the timeline — and everything downstream of it — is
    /// bit-identical across `--workers N`.  Dropped and late clients'
    /// transfers stay in the timeline (their partial traffic contended
    /// for the pipe before the server learned they were gone); OOM-failed
    /// clients never reach the upload phase, but their *download* did
    /// happen — it contends and its events are emitted, so the event
    /// stream accounts for every simulated byte.
    #[allow(clippy::too_many_arguments)]
    fn finish_netsim_round(
        &mut self,
        nr: NetsimRound,
        round: u32,
        selected: &[usize],
        ledger: &mut RoundLedger,
        acc: &mut Box<dyn AggAccumulator>,
        gate: &mut Option<RoundGate>,
        recorder: &mut HistoryObserver,
        tracer: &mut TraceObserver,
        phases: Option<&PhaseRecorder>,
    ) -> Result<Schedule, FlError> {
        // Borrowed, not cloned: `netsim`, `observers` and `dynamics` are
        // disjoint fields, so the long-lived shared borrow here coexists
        // with the mutable borrows the notify/gate calls below take.
        let ns = self.netsim.as_ref().expect("netsim round implies netsim");
        let comm_span = phases.map(|p| p.start(Phase::Comm));
        let NetsimRound { links, download_s, buffered } = nr;
        let uploads: Vec<(f64, NetworkProfile)> = buffered
            .iter()
            .map(|(pos, r)| (download_s[*pos] + r.emu.emu_total_s, links[*pos]))
            .collect();
        let upload_end = ns.upload_finish(&uploads);
        let wire_up = ns.wire_upload_bytes();
        let payload = ns.payload_bytes();

        // Download events for every *selected* client, selection order —
        // a fit that later OOM'd still fetched the model and contended
        // for egress (client ids equal roster indices, the same ledger
        // convention the per-client event merge relies on).
        for (pos, &roster_idx) in selected.iter().enumerate() {
            let client = roster_idx as u32;
            notify(
                recorder,
                tracer,
                &mut self.observers,
                FlEvent::CommStarted {
                    round,
                    client,
                    direction: CommDirection::Download,
                    at_s: 0.0,
                    wire_bytes: payload,
                },
            );
            notify(
                recorder,
                tracer,
                &mut self.observers,
                FlEvent::CommFinished {
                    round,
                    client,
                    direction: CommDirection::Download,
                    at_s: download_s[pos],
                },
            );
        }

        drop(comm_span);

        // Kept spans for the schedule — only tracked when no dynamics
        // gate is active (an active gate records the very same windows
        // via `admit_window` and renders them itself below).
        let gate_span = phases.map(|p| p.start(Phase::Gate));
        let gated = gate.is_some();
        let mut spans: Vec<(u32, f64, f64)> =
            if gated { Vec::new() } else { Vec::with_capacity(buffered.len()) };
        for (k, (pos, mut result)) in buffered.into_iter().enumerate() {
            let client = result.client;
            let upload_start = download_s[pos] + result.emu.emu_total_s;
            let end = upload_end[k];
            notify(
                recorder,
                tracer,
                &mut self.observers,
                FlEvent::CommStarted {
                    round,
                    client,
                    direction: CommDirection::Upload,
                    at_s: upload_start,
                    wire_bytes: wire_up,
                },
            );
            notify(
                recorder,
                tracer,
                &mut self.observers,
                FlEvent::CommFinished {
                    round,
                    client,
                    direction: CommDirection::Upload,
                    at_s: end,
                },
            );
            // The client's full round window is [0, end): simulated comm
            // replaces the client's closed-form `comm_s`, so the ledger
            // duration, the gate window and the scenario timeline all see
            // the contention-aware cost.
            result.comm_s = end - result.emu.emu_total_s;
            let verdict = match self.dynamics.as_mut().zip(gate.as_mut()) {
                Some((d, g)) => d.admit_window(g, selected[pos], client, 0.0, end),
                None => GateVerdict::Keep { start_s: 0.0, end_s: end },
            };
            match verdict {
                GateVerdict::Keep { .. } => {
                    ns.codec_apply(result.params.as_mut_slice());
                    // The attack seam: after codec decode, immediately
                    // before the fold (DESIGN.md §13).
                    if let Some(atk) = self.attack.as_mut() {
                        atk.apply(client, result.params.as_mut_slice());
                    }
                    if !gated {
                        spans.push((client, 0.0, end));
                    }
                    fold(ledger, acc, pos, result)?;
                }
                GateVerdict::Dropout { offline_at_s } => {
                    ledger.record_failure(client, dropout_reason(offline_at_s));
                    acc.skip_indexed(pos);
                }
                GateVerdict::Late { would_end_s } => {
                    let deadline =
                        gate.as_ref().map(|g| g.deadline_s()).unwrap_or(f64::INFINITY);
                    ledger.record_failure(client, late_reason(would_end_s, deadline));
                    acc.skip_indexed(pos);
                }
            }
        }

        // Gate failures were appended after the fit phase's OOM failures,
        // so the failure list may have left selection order — restore it
        // for the per-client event merge (its two-pointer walk relies on
        // selection-ordered partitions).
        if !ledger.failures.is_empty() {
            let position: std::collections::BTreeMap<u32, usize> = ledger
                .selected
                .iter()
                .enumerate()
                .map(|(p, &c)| (c, p))
                .collect();
            ledger
                .failures
                .sort_by_key(|f| position.get(&f.client).copied().unwrap_or(usize::MAX));
        }
        drop(gate_span);

        // Round clock: the gate's view when dynamics are on (it recorded
        // the same kept windows and holds a late round open until the
        // deadline); otherwise the simulated timeline's kept horizon.
        Ok(match gate.as_ref() {
            Some(g) => g.schedule(),
            None => Schedule {
                round_s: spans.iter().map(|&(_, _, e)| e).fold(0.0, f64::max),
                spans,
            },
        })
    }

    /// Centralised eval over the held-out set (batched by the compiled
    /// eval artifact's batch size; a trailing partial batch is padded by
    /// wrapping, standard practice for fixed-shape accelerator eval).
    fn evaluate(
        &self,
        executor: &mut ModelExecutor,
        global: &ParamVector,
    ) -> Option<(f32, f32)> {
        let data = self.eval_data.as_ref()?;
        let batch = executor.eval_batch_size()?;
        let n = data.len();
        if n == 0 {
            return None;
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut seen = 0usize;
        let mut start = 0usize;
        while seen < n {
            let idx: Vec<usize> = (0..batch as usize).map(|i| (start + i) % n).collect();
            let (x, y) = data.gather(&idx);
            let take = (batch as usize).min(n - seen);
            match executor.eval_batch(global, &x, &y, batch) {
                Ok((l, c)) => {
                    // Only count the non-wrapped fraction.
                    let frac = take as f64 / batch as f64;
                    loss_sum += l as f64 * take as f64;
                    correct += c as f64 * frac;
                }
                Err(_) => return None,
            }
            seen += take;
            start += take;
        }
        Some(((loss_sum / n as f64) as f32, (correct / n as f64) as f32))
    }
}

/// The dynamics gate and its backing federation state, threaded through a
/// round as one unit — either both present (scenario active) or neither,
/// so gating can never be half-wired.
type DynGate<'a> = Option<(&'a mut FederationDynamics, &'a mut RoundGate)>;

/// Open a host-domain phase span iff a recorder is attached — the guard
/// records on drop; without one this is a no-op on the hot path.
fn pstart(phases: &Option<PhaseRecorder>, phase: Phase) -> Option<PhaseGuard> {
    phases.as_ref().map(|p| p.start(phase))
}

/// Deliver one event to the built-in subscribers (history first, then
/// trace) and then to every user observer in attach order.
fn notify(
    recorder: &mut HistoryObserver,
    tracer: &mut TraceObserver,
    user: &mut [Box<dyn FlObserver>],
    event: FlEvent<'_>,
) {
    recorder.on_event(&event);
    tracer.on_event(&event);
    for observer in user.iter_mut() {
        observer.on_event(&event);
    }
}

/// End a round: broadcast `RoundEnd` to the trace subscriber and user
/// observers, then hand the *owned* record to the history recorder —
/// same observable sequence as [`notify`], without the per-round deep
/// clone the borrowing event path would force on the recorder.
fn notify_round_end(
    recorder: &mut HistoryObserver,
    tracer: &mut TraceObserver,
    user: &mut [Box<dyn FlObserver>],
    record: RoundRecord,
) {
    let event = FlEvent::RoundEnd { record: &record };
    tracer.on_event(&event);
    for observer in user.iter_mut() {
        observer.on_event(&event);
    }
    recorder.push(record);
}

/// Durable-run round boundary (DESIGN.md §14), called after every
/// `RoundEnd`: flush the event log and snapshot the server's cross-round
/// state when the cadence says so, then fire the fault-injection hook.
/// The checkpoint is taken *after* the flush so its `log_offset` covers
/// every event of the finished round; between rounds the aggregation
/// accumulator and the dynamics gate are provably empty, so the snapshot
/// here is the complete server state.
#[allow(clippy::too_many_arguments)]
fn durable_round_boundary(
    durable: Option<&RunDurability>,
    dynamics: Option<&FederationDynamics>,
    strategy: &dyn Strategy,
    attack: Option<&Attack>,
    total_rounds: u32,
    round: u32,
    global: &ParamVector,
    manager: &ClientManager,
    clock: &VirtualClock,
) -> Result<(), FlError> {
    let Some(d) = durable else { return Ok(()) };
    let durable_err = |e: std::io::Error| FlError::Durable(format!("{}: {e}", d.dir().display()));
    let next_round = round + 1;
    if d.checkpoint_due(next_round, total_rounds) {
        let log_offset = {
            let mut w = d.lock_writer();
            w.sync().map_err(durable_err)?;
            w.offset()
        };
        let ckpt = Checkpoint {
            next_round,
            log_offset,
            every_k: d.every_k(),
            clock_s: clock.now_s(),
            dynamics: dynamics.map(|dy| (dy.rounds_begun(), dy.now_s())),
            manager_rng: manager.rng_state(),
            global: global.as_slice().to_vec(),
            strategy_blob: strategy.state_blob(),
            attack_blob: attack.map(|a| a.state_blob()).unwrap_or_default(),
        };
        ckpt.save(&d.dir().join(CHECKPOINT_FILE)).map_err(durable_err)?;
    }
    if d.should_crash(round) {
        d.lock_writer().sync().map_err(durable_err)?;
        return Err(FlError::Durable(format!(
            "crash point: injected fault after round {round}"
        )));
    }
    Ok(())
}

/// The paper-default engine: fits run sequentially in this thread,
/// each finished client folded into the accumulator immediately (or
/// buffered for the netsim upload timeline).
#[allow(clippy::too_many_arguments)]
fn round_inline(
    roster: &mut Roster,
    host: &HardwareProfile,
    env_cfg: &EnvConfig,
    executor: &mut Option<&mut ModelExecutor>,
    selected: &[usize],
    global: &ParamVector,
    fit_cfg: &FitConfig,
    clock: &mut VirtualClock,
    ledger: &mut RoundLedger,
    acc: &mut Box<dyn AggAccumulator>,
    dyn_gate: &mut DynGate<'_>,
    netsim: &mut Option<NetsimRound>,
    attack: &mut Option<Attack>,
    scratch: &ParamScratch,
) -> Result<(), FlError> {
    for (pos, &ci) in selected.iter().enumerate() {
        let mut client = roster.checkout(ci);
        let id = client.id();
        let fit_result = {
            let mut ctx = BouquetContext {
                executor: executor.as_deref_mut(),
                clock: &mut *clock,
                host,
                env_cfg: env_cfg.clone(),
                scratch: scratch.clone(),
            };
            client.fit(global, fit_cfg, &mut ctx)
        };
        roster.checkin(ci, client);
        match fit_result {
            Ok(result) => {
                fold_gated(ledger, acc, dyn_gate, netsim, attack, pos, ci, result)?
            }
            Err(e @ EmuError::GpuOom { .. }) | Err(e @ EmuError::HostOom { .. }) => {
                // The paper's OOM story: the framework survives a
                // failing client; it simply contributes no update.
                ledger.record_failure(id, e.to_string());
                acc.skip_indexed(pos);
            }
            Err(other) => {
                return Err(FlError::ClientFailed { client: id, source: other });
            }
        }
    }
    Ok(())
}

/// The concurrent engine: fits run on the pool; outcomes stream back
/// in completion order and pass through a reorder buffer so every fold
/// (accumulator, ledger, dynamics gate, shared clock) happens in selection
/// order — bit-identical to the inline engine.
#[allow(clippy::too_many_arguments)]
fn round_pooled(
    roster: &mut Roster,
    host: &HardwareProfile,
    env_cfg: &EnvConfig,
    pool: &WorkerPool,
    selected: &[usize],
    global: &ParamVector,
    fit_cfg: &FitConfig,
    clock: &mut VirtualClock,
    ledger: &mut RoundLedger,
    acc: &mut Box<dyn AggAccumulator>,
    dyn_gate: &mut DynGate<'_>,
    netsim: &mut Option<NetsimRound>,
    attack: &mut Option<Attack>,
    phases: Option<&PhaseRecorder>,
) -> Result<(), FlError> {
    let shared = Arc::new(global.clone());
    // Worker-side folding: only when nothing stands between a successful
    // fit and its fold — a gate can drop/filter the update, netsim buffers
    // it for the upload timeline, and an attack perturbs it at the
    // aggregation seam, so on those rounds every update must travel to the
    // server thread.  Eligibility is a pure function of the round's
    // configuration (never of timing), so the fold location — and with the
    // tree plan's fixed topology, the aggregate — is deterministic.
    let worker_fold = if dyn_gate.is_none() && netsim.is_none() && attack.is_none() {
        acc.worker_fold_handle()
    } else {
        None
    };
    {
        let _dispatch_span = phases.map(|p| p.start(Phase::Dispatch));
        for (pos, &ci) in selected.iter().enumerate() {
            let client = roster.checkout(ci);
            pool.submit(FitTask {
                index: pos,
                client,
                global: Arc::clone(&shared),
                cfg: fit_cfg.clone(),
                host: host.clone(),
                env_cfg: env_cfg.clone(),
                fold: worker_fold.clone(),
            })?;
        }
    }

    let mut reorder = ReorderBuffer::new(selected.len());
    let mut fatal: Option<FlError> = None;
    for _ in 0..selected.len() {
        let outcome = pool.recv()?;
        roster.checkin(selected[outcome.index], outcome.client);
        reorder.accept(FitOutcomeSlim {
            index: outcome.index,
            client_id: outcome.client_id,
            result: outcome.result,
        });
        while let Some(slim) = reorder.pop_ready() {
            // Once the round is doomed, keep draining (every client must
            // come back) but stop folding — the first error is the one
            // the caller sees.
            if fatal.is_some() {
                continue;
            }
            match slim.result {
                Ok(result) => {
                    // Replay the emulated time the inline engine would
                    // have advanced during this fit, increment for
                    // increment (bit-identical clock trajectory).
                    clock.advance(result.emu.warmup_s);
                    for _ in 0..result.emu.steps {
                        clock.advance(result.emu.step_s);
                    }
                    if let Err(e) = fold_gated(
                        ledger,
                        acc,
                        dyn_gate,
                        netsim,
                        attack,
                        slim.index,
                        selected[slim.index],
                        result,
                    ) {
                        fatal = Some(e);
                    }
                }
                Err(e @ EmuError::GpuOom { .. })
                | Err(e @ EmuError::HostOom { .. }) => {
                    ledger.record_failure(slim.client_id, e.to_string());
                    // Safe double-skip when a worker held the fold handle:
                    // TreeFoldState::skip is idempotent.
                    acc.skip_indexed(slim.index);
                }
                Err(other) => {
                    fatal = Some(FlError::ClientFailed {
                        client: slim.client_id,
                        source: other,
                    });
                }
            }
        }
    }
    if let Some(p) = phases {
        p.gauge_max("reorder_peak_held_back", reorder.peak_held_back() as f64);
    }
    // All clients are checked back in; only now surface a fatal error
    // (same observable as the inline engine's early return).
    match fatal {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Failure reason for a mid-window disconnection — one formatter shared
/// by the packed and netsim gate paths, so `FailureKind::classify` (and
/// the dynamics report) see one vocabulary.
fn dropout_reason(offline_at_s: f64) -> String {
    format!(
        "{} client went offline at {offline_at_s:.2}s (emulated) \
         before completing its fit+upload window",
        super::history::DROPOUT_REASON_PREFIX
    )
}

/// Failure reason for a deadline miss (see [`dropout_reason`]).
fn late_reason(would_end_s: f64, deadline_s: f64) -> String {
    format!(
        "{} fit+comm would finish at {would_end_s:.2}s, past the \
         {deadline_s:.2}s round deadline",
        super::history::DEADLINE_REASON_PREFIX
    )
}

/// Fold one successful fit through the dynamics gate (if any) into the
/// round's scalar ledger and the streaming aggregate.
///
/// Without dynamics this is exactly the static fold.  With dynamics the
/// gate decides `Keep` / `Dropout` / `Late` over the client's full
/// fit+comm window — dropped and late clients are recorded as round
/// failures and **never reach the accumulator**.  The replay clock is
/// untouched here (its trajectory stays identical to the static engine);
/// comm time reaches the scenario timeline through the round length.
///
/// A netsim round defers all of this: upload completion times depend on
/// every arrival in the round, so the result is buffered (in selection
/// order — the reorder buffer guarantees the feed order on any engine)
/// and `ServerApp::finish_netsim_round` gates and folds once the shared
/// timeline is solvable.
#[allow(clippy::too_many_arguments)]
fn fold_gated(
    ledger: &mut RoundLedger,
    acc: &mut Box<dyn AggAccumulator>,
    dyn_gate: &mut DynGate<'_>,
    netsim: &mut Option<NetsimRound>,
    attack: &mut Option<Attack>,
    pos: usize,
    roster_idx: usize,
    mut result: FitResult,
) -> Result<(), FlError> {
    if let Some(nr) = netsim {
        // Attack injection is deferred with the fold: the codec decodes
        // the buffered update first, then `finish_netsim_round` perturbs
        // and folds it.
        nr.buffered.push((pos, result));
        return Ok(());
    }
    let (dynamics, gate) = match dyn_gate {
        Some((d, g)) => (d, g),
        None => {
            inject(attack, &mut result);
            return fold(ledger, acc, pos, result);
        }
    };
    let dur_s = result.emu.emu_total_s + result.comm_s;
    match dynamics.admit(gate, roster_idx, result.client, dur_s) {
        GateVerdict::Keep { .. } => {
            inject(attack, &mut result);
            fold(ledger, acc, pos, result)
        }
        GateVerdict::Dropout { offline_at_s } => {
            ledger.record_failure(result.client, dropout_reason(offline_at_s));
            acc.skip_indexed(pos);
            Ok(())
        }
        GateVerdict::Late { would_end_s } => {
            ledger.record_failure(
                result.client,
                late_reason(would_end_s, gate.deadline_s()),
            );
            acc.skip_indexed(pos);
            Ok(())
        }
    }
}

/// The attack seam for the non-netsim paths: perturb a *kept* update in
/// place iff its client is compromised, immediately before the
/// accumulator fold (DESIGN.md §13).  Gate-rejected updates never get
/// here — an attacker that drops out or misses the deadline injects
/// nothing, exactly like an honest client contributes nothing.
fn inject(attack: &mut Option<Attack>, result: &mut FitResult) {
    if let Some(atk) = attack {
        atk.apply(result.client, result.params.as_mut_slice());
    }
}

/// Fold one success into the round's scalar ledger and the streaming
/// aggregate; the `FitResult` (and its param vector) dies here.  `pos` is
/// the client's selection index — the reduction key a position-aware
/// accumulator (the tree fold) routes on.
fn fold(
    ledger: &mut RoundLedger,
    acc: &mut Box<dyn AggAccumulator>,
    pos: usize,
    result: FitResult,
) -> Result<(), FlError> {
    ledger.record_success(&result);
    acc.push_indexed(pos, result)
}
