//! Crash-recovery proof harness for the durable run infrastructure
//! (DESIGN.md §14).
//!
//! What is exercised here, deterministically and in-process:
//!
//! - **Torn-write sweeps** — the event log is cut at *every* byte offset
//!   and every byte is flipped in place; the reader must always recover
//!   exactly the maximal clean prefix and never panic.
//! - **Bit-identical resume** — runs are crashed at a round boundary via
//!   the injected [`CrashPoint`](bouquetfl::durable::CrashPoint) fault
//!   (the on-disk state of a SIGKILL between rounds) and resumed; the
//!   merged outputs must match an uninterrupted run bit for bit across
//!   scenarios × worker counts × {netsim, attack, plain} axes.
//! - **Replay-vs-live equivalence** — a log alone must reconstruct the
//!   live run's history/trace/report byte-identically, for materialized
//!   and population-scale federations.
//! - **Campaign recovery** — a doctored half-finished sweep directory
//!   (torn trailing row + rewound cursor) resumes to the exact bytes of
//!   a never-interrupted campaign, and a mismatched grid is rejected.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use bouquetfl::durable::eventlog::LOG_HEADER_LEN;
use bouquetfl::durable::{
    parse_log, replay, Checkpoint, DurableOptions, LogMeta, OwnedFlEvent, CHECKPOINT_FILE,
    EVENT_LOG_FILE,
};
use bouquetfl::fl::history::FailureRecord;
use bouquetfl::fl::launcher::{HardwareSource, LaunchOptions};
use bouquetfl::fl::{
    Campaign, CommDirection, Experiment, ExperimentBuilder, ExperimentReport, History,
    ParamVector, RoundRecord, Scenario, Selection,
};
use bouquetfl::sched::Schedule;

const PROFILES: [&str; 2] = ["gtx-1060", "rtx-3060"];

// ---------------------------------------------------------------------------
// Scratch directories (no tempfile dependency).

static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let path = std::env::temp_dir().join(format!(
            "bouquetfl-durable-{tag}-{}-{}",
            std::process::id(),
            DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&path);
        TempDir(path)
    }

    fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

// ---------------------------------------------------------------------------
// Shared experiment shapes and bit-exact comparison helpers.

/// The orthogonal feature axis a matrix cell runs under.
#[derive(Clone, Copy, Debug)]
enum Axis {
    /// No netsim, no attack (fedavgm keeps cross-round strategy state).
    Plain,
    /// Contention-aware communication timeline (fedadam: two moments).
    Netsim,
    /// Sign-flip poisoning on a random participant subset.
    Attack,
}

fn sim_experiment(scenario: &str, workers: usize, axis: Axis, seed: u64) -> ExperimentBuilder {
    let b = Experiment::builder()
        .clients(6)
        .rounds(7)
        .profiles(&PROFILES)
        .workers(workers)
        .seed(seed)
        .eval_every(0)
        .fail_on_empty_round(false)
        .scenario_named(scenario)
        .simulated(24);
    match axis {
        Axis::Plain => b.strategy("fedavgm").selection(Selection::All),
        Axis::Netsim => b
            .strategy("fedadam")
            .selection(Selection::Count(4))
            .netsim_named("congested-cell"),
        Axis::Attack => b
            .strategy("fedavg")
            .selection(Selection::Fraction(0.5))
            .attack_named("sign-flip"),
    }
}

fn run_ok(builder: ExperimentBuilder, label: &str) -> ExperimentReport {
    builder
        .build()
        .unwrap_or_else(|e| panic!("{label}: build failed: {e}"))
        .run()
        .unwrap_or_else(|e| panic!("{label}: run failed: {e}"))
}

/// Run with an injected crash point and assert the run died at it.
fn run_crash(builder: ExperimentBuilder, opts: DurableOptions, label: &str) {
    let outcome = builder
        .durable_options(opts)
        .build()
        .unwrap_or_else(|e| panic!("{label}: build failed: {e}"))
        .run();
    match outcome {
        Ok(_) => panic!("{label}: crash-point run unexpectedly succeeded"),
        Err(e) => {
            let msg = format!("{e}");
            assert!(msg.contains("crash point"), "{label}: unexpected error: {msg}");
        }
    }
}

/// History canonicalized for resume comparisons: `host_round_s` measures
/// *this process's* wall clock, so a resumed run legitimately differs
/// there (its early rounds carry the crashed process's timings).  Every
/// other field must survive bit-exactly, which the JSON encoding (exact
/// shortest-roundtrip floats) preserves.
fn canon_history(h: &History) -> String {
    let mut h = h.clone();
    for r in &mut h.rounds {
        r.host_round_s = 0.0;
    }
    h.to_json().pretty()
}

fn global_bits(p: &ParamVector) -> Vec<u32> {
    p.as_slice().iter().map(|x| x.to_bits()).collect()
}

fn assert_same_run(label: &str, a: &ExperimentReport, b: &ExperimentReport) {
    assert_eq!(canon_history(&a.history), canon_history(&b.history), "{label}: history");
    assert_eq!(global_bits(&a.global), global_bits(&b.global), "{label}: global model");
    assert_eq!(
        a.trace.to_chrome_json().dump(),
        b.trace.to_chrome_json().dump(),
        "{label}: trace"
    );
}

// ---------------------------------------------------------------------------
// Frame-level encoding.

/// One of every [`OwnedFlEvent`] variant, with edge shapes (empty vecs,
/// `None` evals, empty strings) mixed in.
fn sample_events() -> Vec<OwnedFlEvent> {
    vec![
        OwnedFlEvent::Meta(LogMeta {
            strategy: "fedadam".into(),
            scenario: "high-churn".into(),
            seed: u64::MAX - 1,
            rounds: 12,
            clients: 50_000,
        }),
        OwnedFlEvent::RunBegin { rounds: 12, clients: 50_000 },
        OwnedFlEvent::RoundBegin { round: 3, selected: vec![0, 5, 2] },
        OwnedFlEvent::RoundBegin { round: 4, selected: vec![] },
        OwnedFlEvent::RoundSkipped { round: 5, wait_s: 12.25 },
        OwnedFlEvent::ClientDone { round: 3, client: 5, fit_s: 8.5 },
        OwnedFlEvent::ClientFailed {
            round: 3,
            client: 2,
            reason: "dropout: went offline at 4.5s".into(),
        },
        OwnedFlEvent::ClientFailed { round: 3, client: 0, reason: String::new() },
        OwnedFlEvent::AttackInjected { round: 3, client: 5, model: "sign-flip".into() },
        OwnedFlEvent::CommStarted {
            round: 3,
            client: 5,
            direction: CommDirection::Download,
            at_s: 0.5,
            wire_bytes: 1 << 20,
        },
        OwnedFlEvent::CommFinished {
            round: 3,
            client: 5,
            direction: CommDirection::Upload,
            at_s: 9.75,
        },
        OwnedFlEvent::RoundScheduled {
            round: 3,
            base_s: 100.0,
            schedule: Schedule {
                round_s: 9.75,
                spans: vec![(5, 0.5, 9.75), (0, 0.0, 0.0)],
            },
        },
        OwnedFlEvent::Aggregated { round: 3, survivors: 1 },
        OwnedFlEvent::Evaluated { round: 3, loss: 0.625, accuracy: 0.5 },
        OwnedFlEvent::RoundEnd {
            record: RoundRecord {
                round: 3,
                selected: vec![5, 2, 0],
                failures: vec![FailureRecord { client: 2, reason: "late".into() }],
                train_loss: 0.75,
                eval_loss: Some(0.5),
                eval_accuracy: Some(0.25),
                emu_round_s: 9.75,
                host_round_s: 0.001953125,
            },
        },
        OwnedFlEvent::RoundEnd {
            record: RoundRecord {
                round: 4,
                selected: vec![],
                failures: vec![],
                train_loss: 1.5,
                eval_loss: None,
                eval_accuracy: None,
                emu_round_s: 0.0,
                host_round_s: 0.0,
            },
        },
        OwnedFlEvent::RunEnd { rounds: 12 },
    ]
}

#[test]
fn every_event_variant_roundtrips_and_rejects_torn_payloads() {
    for ev in sample_events() {
        let payload = ev.encode();
        assert_eq!(OwnedFlEvent::decode(&payload).as_ref(), Some(&ev), "roundtrip {ev:?}");
        // Every strict prefix leaves some declared field short; the
        // decoder must refuse rather than fabricate a partial event.
        for cut in 0..payload.len() {
            assert!(
                OwnedFlEvent::decode(&payload[..cut]).is_none(),
                "{ev:?}: cut at {cut} decoded"
            );
        }
        // Trailing garbage is equally rejected (exact-length contract).
        let mut padded = payload.clone();
        padded.push(0);
        assert!(OwnedFlEvent::decode(&padded).is_none(), "{ev:?}: trailing byte accepted");
    }
}

// ---------------------------------------------------------------------------
// Torn-write and bit-flip sweeps over a real log.

/// A feature-dense durable run (churn + netsim + attack) whose log holds
/// most event kinds; returns the raw log bytes.
fn rich_log_bytes(dir: &Path) -> Vec<u8> {
    run_ok(
        Experiment::builder()
            .clients(4)
            .rounds(3)
            .profiles(&PROFILES)
            .seed(91)
            .eval_every(0)
            .fail_on_empty_round(false)
            .scenario_named("high-churn")
            .strategy("fedadam")
            .selection(Selection::Count(3))
            .netsim_named("congested-cell")
            .attack_named("sign-flip")
            .simulated(24)
            .durable(dir),
        "torn-write source run",
    );
    std::fs::read(dir.join(EVENT_LOG_FILE)).unwrap()
}

/// All valid clean-prefix ends of a log: the bare header, the end of the
/// meta frame, and the end of every event frame.
fn frame_boundaries(bytes: &[u8], offsets: &[u64]) -> Vec<u64> {
    let hdr = LOG_HEADER_LEN as usize;
    let meta_len =
        u32::from_le_bytes(bytes[hdr..hdr + 4].try_into().unwrap()) as u64;
    let mut boundaries = vec![LOG_HEADER_LEN, LOG_HEADER_LEN + 8 + meta_len];
    boundaries.extend_from_slice(offsets);
    boundaries
}

#[test]
fn torn_write_sweep_recovers_the_maximal_clean_prefix_at_every_offset() {
    let dir = TempDir::new("torn");
    let bytes = rich_log_bytes(dir.path());
    let full = parse_log(&bytes);
    assert!(!full.truncated, "pristine log reported a torn tail");
    assert_eq!(full.clean_offset, bytes.len() as u64, "pristine log not fully clean");
    assert!(full.meta.is_some(), "log lost its meta frame");
    assert!(full.events.len() > 30, "log too sparse to be a meaningful sweep");
    assert!(
        matches!(full.events.last(), Some(OwnedFlEvent::RunEnd { .. })),
        "completed run must end with RunEnd"
    );

    let boundaries = frame_boundaries(&bytes, &full.offsets);
    let meta_end = boundaries[1];
    for cut in 0..=bytes.len() {
        let r = parse_log(&bytes[..cut]);
        let expect = boundaries.iter().copied().filter(|&b| b <= cut as u64).max().unwrap_or(0);
        assert_eq!(r.clean_offset, expect, "cut at {cut}: clean offset");
        assert_eq!(r.truncated, expect != cut as u64, "cut at {cut}: truncated flag");
        assert_eq!(r.meta.is_some(), expect >= meta_end, "cut at {cut}: meta");
        let keep = full.offsets.iter().filter(|&&end| end <= expect).count();
        assert_eq!(r.events.len(), keep, "cut at {cut}: event count");
        assert_eq!(r.events[..], full.events[..keep], "cut at {cut}: event prefix");
        assert_eq!(r.offsets[..], full.offsets[..keep], "cut at {cut}: offsets");
    }
}

#[test]
fn bit_flip_sweep_stops_at_the_corrupted_frame_and_never_panics() {
    let dir = TempDir::new("flip");
    let mut bytes = rich_log_bytes(dir.path());
    let full = parse_log(&bytes);
    let boundaries = frame_boundaries(&bytes, &full.offsets);
    let meta_end = boundaries[1];
    for i in 0..bytes.len() {
        bytes[i] ^= 0xA5;
        let r = parse_log(&bytes);
        // The flipped byte lives in the frame that starts at the last
        // boundary at or before it; CRC-32 (or the header check, or the
        // strict decoder) must reject exactly that frame.
        let expect = boundaries.iter().copied().filter(|&b| b <= i as u64).max().unwrap_or(0);
        assert_eq!(r.clean_offset, expect, "flip at {i}: clean offset");
        assert!(r.truncated, "flip at {i}: corruption went unnoticed");
        assert_eq!(r.meta.is_some(), expect >= meta_end, "flip at {i}: meta");
        let keep = full.offsets.iter().filter(|&&end| end <= expect).count();
        assert_eq!(r.events.len(), keep, "flip at {i}: event count");
        assert_eq!(r.events[..], full.events[..keep], "flip at {i}: event prefix");
        bytes[i] ^= 0xA5;
    }
}

#[test]
fn checkpoint_rejects_every_single_byte_corruption_and_truncation() {
    let dir = TempDir::new("ckpt");
    std::fs::create_dir_all(dir.path()).unwrap();
    let path = dir.path().join(CHECKPOINT_FILE);
    let ckpt = Checkpoint {
        next_round: 5,
        log_offset: 4096,
        every_k: 2,
        clock_s: 123.5,
        dynamics: Some((40, 123.5)),
        manager_rng: (0x0123_4567_89ab_cdef, 0x1111_2222_3333_4444),
        global: vec![0.5, -1.25, 3.0, 0.0],
        strategy_blob: vec![1, 2, 3, 4, 5],
        attack_blob: vec![9],
    };
    ckpt.save(&path).unwrap();
    assert_eq!(Checkpoint::load(&path).unwrap(), ckpt);

    let bytes = std::fs::read(&path).unwrap();
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0x01;
        std::fs::write(&path, &bad).unwrap();
        assert!(Checkpoint::load(&path).is_err(), "flip at {i} accepted");
    }
    for cut in 0..bytes.len() {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(Checkpoint::load(&path).is_err(), "truncation to {cut} accepted");
    }
    std::fs::write(&path, &bytes).unwrap();
    assert_eq!(Checkpoint::load(&path).unwrap(), ckpt, "pristine bytes stopped loading");
}

// ---------------------------------------------------------------------------
// Crash + resume bit-identity.

#[test]
fn resume_is_bit_identical_across_scenarios_workers_and_axes() {
    let axes = [Axis::Plain, Axis::Netsim, Axis::Attack];
    // Crash early, mid-run, and at the last resumable boundary of the
    // 7-round runs (rounds are 0-based; every_k = 1 checkpoints every
    // boundary except the final one).
    let crash_rounds = [1u32, 3, 5];
    for (si, scenario) in ["stable", "diurnal-mobile", "high-churn"].iter().enumerate() {
        for &workers in &[1usize, 4] {
            for (ai, &axis) in axes.iter().enumerate() {
                let crash_at = crash_rounds[ai];
                let seed = 1000 + (si * 100 + ai * 10 + workers) as u64;
                let label = format!("{scenario}/workers={workers}/{axis:?}/crash@{crash_at}");

                let crash_dir = TempDir::new("resume-crash");
                let clean_dir = TempDir::new("resume-clean");

                run_crash(
                    sim_experiment(scenario, workers, axis, seed),
                    DurableOptions::new(crash_dir.path()).crash_after(crash_at),
                    &label,
                );
                assert!(
                    crash_dir.path().join(CHECKPOINT_FILE).exists(),
                    "{label}: crashed run left no checkpoint"
                );
                let resumed = run_ok(
                    sim_experiment(scenario, workers, axis, seed).resume(crash_dir.path()),
                    &format!("{label} (resume)"),
                );

                let unbroken = run_ok(
                    sim_experiment(scenario, workers, axis, seed).durable(clean_dir.path()),
                    &format!("{label} (uninterrupted durable)"),
                );
                let plain = run_ok(
                    sim_experiment(scenario, workers, axis, seed),
                    &format!("{label} (no durability)"),
                );

                assert_eq!(
                    resumed.history.rounds.len(),
                    7,
                    "{label}: resumed run lost rounds"
                );
                assert_same_run(&format!("{label}: resumed vs uninterrupted"), &resumed, &unbroken);
                // Durability must be observationally free: attaching the
                // log/checkpoint machinery cannot perturb the run.
                assert_same_run(&format!("{label}: durable vs plain"), &unbroken, &plain);
            }
        }
    }
}

#[test]
fn resume_truncates_post_checkpoint_events_with_sparse_cadence() {
    // every_k = 2, crash after round 2: the last checkpoint covers rounds
    // 0-1 only, so round 2's events sit past the snapshot in the log and
    // must be truncated + re-run on resume, not double-counted.
    let crash_dir = TempDir::new("sparse-crash");
    let clean_dir = TempDir::new("sparse-clean");
    let mk = |seed| {
        sim_experiment("diurnal-mobile", 1, Axis::Plain, seed)
            .rounds(6)
    };

    run_crash(
        mk(77),
        DurableOptions::new(crash_dir.path()).every(2).crash_after(2),
        "sparse cadence",
    );
    let ckpt = Checkpoint::load(&crash_dir.path().join(CHECKPOINT_FILE)).unwrap();
    assert_eq!(ckpt.next_round, 2, "checkpoint should cover exactly rounds 0-1");
    assert_eq!(ckpt.every_k, 2, "cadence must persist in the snapshot");
    let log = parse_log(&std::fs::read(crash_dir.path().join(EVENT_LOG_FILE)).unwrap());
    let last_logged = log
        .events
        .iter()
        .rev()
        .find_map(|e| match e {
            OwnedFlEvent::RoundEnd { record } => Some(record.round),
            _ => None,
        })
        .expect("crashed log has no finished round");
    assert_eq!(last_logged, 2, "round 2 should be logged beyond the checkpoint");

    let resumed = run_ok(mk(77).resume(crash_dir.path()), "sparse cadence (resume)");
    let unbroken = run_ok(mk(77).durable(clean_dir.path()), "sparse cadence (clean)");
    assert_same_run("sparse cadence", &resumed, &unbroken);

    // The merged log must hold each round exactly once, then RunEnd.
    let merged = parse_log(&std::fs::read(crash_dir.path().join(EVENT_LOG_FILE)).unwrap());
    assert!(!merged.truncated, "merged log has a torn tail");
    let rounds: Vec<u32> = merged
        .events
        .iter()
        .filter_map(|e| match e {
            OwnedFlEvent::RoundEnd { record } => Some(record.round),
            _ => None,
        })
        .collect();
    assert_eq!(rounds, (0..6).collect::<Vec<u32>>(), "duplicated or missing rounds");
    assert!(
        matches!(merged.events.last(), Some(OwnedFlEvent::RunEnd { .. })),
        "merged log must end with RunEnd"
    );
}

#[test]
fn adaptive_attack_state_survives_resume() {
    // The adaptive attacker carries cross-round state (its boost ramps on
    // aggregate feedback); a resume that dropped it would diverge.
    let crash_dir = TempDir::new("adaptive-crash");
    let clean_dir = TempDir::new("adaptive-clean");
    let mk = |seed| {
        Experiment::builder()
            .clients(6)
            .rounds(8)
            .profiles(&PROFILES)
            .seed(seed)
            .eval_every(0)
            .fail_on_empty_round(false)
            .strategy("fedavgm")
            .selection(Selection::All)
            .attack_named("adaptive")
            .simulated(24)
    };

    run_crash(
        mk(31),
        DurableOptions::new(crash_dir.path()).crash_after(4),
        "adaptive attack",
    );
    let resumed = run_ok(mk(31).resume(crash_dir.path()), "adaptive attack (resume)");
    let unbroken = run_ok(mk(31).durable(clean_dir.path()), "adaptive attack (clean)");
    assert_same_run("adaptive attack", &resumed, &unbroken);
}

#[test]
fn log_only_runs_cannot_resume() {
    // every_k = 0 records the log but never snapshots: after a crash
    // there is nothing to restart from, and resume must say so rather
    // than silently re-run from scratch.
    let dir = TempDir::new("log-only");
    run_crash(
        sim_experiment("stable", 1, Axis::Plain, 5),
        DurableOptions::new(dir.path()).every(0).crash_after(2),
        "log-only",
    );
    assert!(dir.path().join(EVENT_LOG_FILE).exists(), "log-only run wrote no log");
    assert!(
        !dir.path().join(CHECKPOINT_FILE).exists(),
        "every_k = 0 must never write a checkpoint"
    );
    let outcome = sim_experiment("stable", 1, Axis::Plain, 5)
        .resume(dir.path())
        .build()
        .expect("resume builds fine; the failure is at run time")
        .run();
    match outcome {
        Ok(_) => panic!("resuming an unresumable run succeeded"),
        Err(e) => {
            let msg = format!("{e}");
            assert!(msg.contains("durable run"), "unexpected error class: {msg}");
        }
    }
}

// ---------------------------------------------------------------------------
// Replay-vs-live equivalence.

fn assert_replay_matches(label: &str, dir: &Path, report: &ExperimentReport) {
    let rp = replay(&dir.join(EVENT_LOG_FILE)).unwrap();
    assert!(rp.complete, "{label}: completed run replays as unfinished");
    assert!(!rp.truncated, "{label}: clean log replays as torn");
    // No canonicalization here: the log embeds the live host timings, so
    // the reconstruction is *byte*-identical, not merely equivalent.
    assert_eq!(
        rp.history.to_json().pretty(),
        report.history.to_json().pretty(),
        "{label}: replayed history"
    );
    assert_eq!(
        rp.trace.to_chrome_json().dump(),
        report.trace.to_chrome_json().dump(),
        "{label}: replayed trace"
    );
    assert_eq!(
        rp.report_json().pretty(),
        report.to_json().pretty(),
        "{label}: replayed report row"
    );
}

#[test]
fn replay_reconstructs_a_materialized_run_byte_identically() {
    let dir = TempDir::new("replay-mat");
    let report = run_ok(
        Experiment::builder()
            .clients(6)
            .rounds(5)
            .profiles(&PROFILES)
            .seed(19)
            .eval_every(0)
            .fail_on_empty_round(false)
            .scenario_named("high-churn")
            .strategy("fedadam")
            .selection(Selection::Count(4))
            .netsim_named("congested-cell")
            .attack_named("sign-flip")
            .simulated(24)
            .durable(dir.path()),
        "replay (materialized)",
    );
    assert_replay_matches("materialized", dir.path(), &report);
}

#[test]
fn replay_reconstructs_a_population_run_byte_identically() {
    let dir = TempDir::new("replay-pop");
    let report = run_ok(
        Experiment::builder()
            .population(50_000)
            .rounds(3)
            .seed(23)
            .eval_every(0)
            .fail_on_empty_round(false)
            .scenario_named("high-churn")
            .selection(Selection::Count(32))
            .simulated(24)
            .durable(dir.path()),
        "replay (population)",
    );
    let rp = replay(&dir.path().join(EVENT_LOG_FILE)).unwrap();
    let meta = rp.meta.as_ref().expect("population log lost its meta frame");
    assert_eq!(meta.clients, 50_000, "meta must record the population size");
    assert_replay_matches("population", dir.path(), &report);
}

// ---------------------------------------------------------------------------
// Campaign-level recovery.

fn small_campaign(seeds: &[u64]) -> Campaign {
    let base = LaunchOptions {
        clients: 4,
        rounds: 2,
        seed: 11,
        eval_every: 0,
        hardware: HardwareSource::Manual(PROFILES.iter().map(|s| s.to_string()).collect()),
        fail_on_empty_round: false,
        ..Default::default()
    };
    Campaign::new("crash-recovery", base)
        .seeds(seeds)
        .strategies(&["fedavg", "fedavgm"])
        .scenarios(&[Scenario::default()])
        .simulated(16)
}

#[test]
fn campaign_resume_completes_a_doctored_run_to_the_clean_bytes() {
    let clean_dir = TempDir::new("campaign-clean");
    let crash_dir = TempDir::new("campaign-crash");
    let campaign = small_campaign(&[1, 2]);

    let clean = campaign.run_durable(clean_dir.path()).unwrap();
    assert_eq!(clean.cells.len(), 4);
    assert_eq!(clean.succeeded(), 4, "clean campaign had error cells");

    // Forge a mid-sweep SIGKILL: run fully, then rewind the directory to
    // "one cell finished, a second row torn mid-write".
    campaign.run_durable(crash_dir.path()).unwrap();
    let cells_path = crash_dir.path().join("cells.jsonl");
    let rows = std::fs::read_to_string(&cells_path).unwrap();
    let first_row_end = rows.find('\n').expect("no complete row") + 1;
    let mut doctored = rows[..first_row_end].to_string();
    doctored.push_str("{\"seed\": 2, \"strat"); // torn tail, no newline
    std::fs::write(&cells_path, doctored).unwrap();
    let cursor_path = crash_dir.path().join("cursor");
    let cursor = std::fs::read_to_string(&cursor_path).unwrap();
    let lines: Vec<&str> = cursor.lines().collect();
    assert_eq!(lines.len(), 3, "unexpected cursor shape: {cursor:?}");
    assert_eq!(lines[2], "4", "full campaign cursor should record 4 cells");
    std::fs::write(&cursor_path, format!("{}\n{}\n1\n", lines[0], lines[1])).unwrap();

    // A different grid must be refused outright.
    let err = small_campaign(&[1, 2, 3]).resume_from(crash_dir.path()).unwrap_err();
    assert!(format!("{err}").contains("grid mismatch"), "wrong rejection: {err}");

    // The matching grid finishes the remaining three cells, dropping the
    // torn row, and lands on the uninterrupted run's exact bytes.
    let resumed = campaign.resume_from(crash_dir.path()).unwrap();
    assert_eq!(resumed.cells.len(), 3, "resume should run only the unfinished cells");
    assert_eq!(resumed.succeeded(), 3);
    assert_eq!(
        std::fs::read_to_string(&cells_path).unwrap(),
        std::fs::read_to_string(clean_dir.path().join("cells.jsonl")).unwrap(),
        "resumed campaign rows differ from the uninterrupted run"
    );
}

#[test]
fn campaign_resume_rejects_a_cursor_past_the_recorded_rows() {
    let dir = TempDir::new("campaign-ahead");
    let campaign = small_campaign(&[1, 2]);
    campaign.run_durable(dir.path()).unwrap();
    // Claim 4 finished cells but leave only one row behind: the cursor
    // lies, and resume must refuse instead of fabricating results.
    let cells_path = dir.path().join("cells.jsonl");
    let rows = std::fs::read_to_string(&cells_path).unwrap();
    let first_row_end = rows.find('\n').unwrap() + 1;
    std::fs::write(&cells_path, &rows[..first_row_end]).unwrap();
    assert!(campaign.resume_from(dir.path()).is_err());
}
