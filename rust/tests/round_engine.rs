//! Concurrent round engine vs the sequential engine: same federation, same
//! seed, `workers = 1` vs `workers = 4` — every emulated observable
//! (schedule, clock, losses, aggregate bits) must be identical; only host
//! wall-clock may differ.  No PJRT artifacts needed: clients are stubs and
//! the server runs executor-less via `run_from`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use bouquetfl::emu::{FitReport, VirtualClock};
use bouquetfl::error::EmuError;
use bouquetfl::fl::{
    BouquetContext, ClientApp, ClientId, FedAvg, FitConfig, FitResult, ParamVector,
    Selection, ServerApp, ServerConfig, TrimmedMean,
};
use bouquetfl::hardware::HardwareProfile;
use bouquetfl::sched::{Sequential, WorkerPool};

const P: usize = 64;

/// Deterministic stub client: burns `work_ms` of real time (so pool
/// speedup is observable), advances the emulated clock exactly like a
/// restricted fit would, and returns params that depend only on its id.
struct StubClient {
    id: ClientId,
    profile: HardwareProfile,
    work_ms: u64,
    /// `Some(e)`: fail every fit with this error instead.
    fail_with: Option<EmuError>,
    /// Panic mid-fit instead of returning (worker containment test).
    panic_in_fit: bool,
}

impl StubClient {
    fn new(id: ClientId, work_ms: u64) -> Self {
        StubClient {
            id,
            profile: HardwareProfile::paper_host(),
            work_ms,
            fail_with: None,
            panic_in_fit: false,
        }
    }

    fn params(&self) -> ParamVector {
        ParamVector::from_vec(
            (0..P)
                .map(|j| ((self.id as usize * 31 + j) % 17) as f32 * 0.1)
                .collect(),
        )
    }
}

impl ClientApp for StubClient {
    fn id(&self) -> ClientId {
        self.id
    }

    fn profile(&self) -> &HardwareProfile {
        &self.profile
    }

    fn num_examples(&self) -> usize {
        10 + self.id as usize
    }

    fn fit(
        &mut self,
        _global: &ParamVector,
        cfg: &FitConfig,
        ctx: &mut BouquetContext<'_>,
    ) -> Result<FitResult, EmuError> {
        if self.panic_in_fit {
            panic!("stub fit panic (client {})", self.id);
        }
        if let Some(e) = &self.fail_with {
            return Err(e.clone());
        }
        std::thread::sleep(Duration::from_millis(self.work_ms));
        let emu = FitReport::synthetic(cfg.local_steps, cfg.batch, 1.0 + self.id as f64);
        // Advance emulated time the way a restricted fit does, increment
        // by increment — the pooled engine replays exactly this.
        ctx.clock.advance(emu.warmup_s);
        for _ in 0..emu.steps {
            ctx.clock.advance(emu.step_s);
        }
        Ok(FitResult {
            client: self.id,
            params: self.params(),
            num_examples: self.num_examples(),
            mean_loss: 1.0 / (1.0 + self.id as f32),
            emu,
            comm_s: 0.0,
        })
    }
}

fn server(clients: Vec<Box<dyn ClientApp>>, workers: usize) -> ServerApp {
    let cfg = ServerConfig {
        rounds: 3,
        selection: Selection::All,
        eval_every: 0,
        seed: 11,
        ..Default::default()
    };
    let s = ServerApp::new(
        cfg,
        HardwareProfile::paper_host(),
        Box::new(FedAvg),
        Box::new(Sequential),
        clients,
    );
    if workers > 1 {
        s.with_round_engine(workers, None)
    } else {
        s
    }
}

fn stub_fleet(n: u32, work_ms: u64) -> Vec<Box<dyn ClientApp>> {
    (0..n).map(|i| Box::new(StubClient::new(i, work_ms)) as Box<dyn ClientApp>).collect()
}

#[test]
fn pooled_round_is_bit_identical_to_sequential() {
    let init = ParamVector::zeros(P);

    let mut seq = server(stub_fleet(8, 0), 1);
    let mut seq_clock = VirtualClock::fast_forward();
    let (g1, h1) = seq.run_from(init.clone(), None, &mut seq_clock).unwrap();

    let mut par = server(stub_fleet(8, 0), 4);
    let mut par_clock = VirtualClock::fast_forward();
    let (g2, h2) = par.run_from(init, None, &mut par_clock).unwrap();

    // Aggregates: bit-identical.
    assert_eq!(g1.len(), g2.len());
    for (a, b) in g1.as_slice().iter().zip(g2.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits(), "aggregate drifted across engines");
    }
    // Emulated history: bit-identical rounds.
    assert_eq!(h1.rounds.len(), h2.rounds.len());
    for (r1, r2) in h1.rounds.iter().zip(&h2.rounds) {
        assert_eq!(r1.selected, r2.selected);
        assert_eq!(r1.train_loss.to_bits(), r2.train_loss.to_bits());
        assert_eq!(r1.emu_round_s.to_bits(), r2.emu_round_s.to_bits());
    }
    // Shared emulated clock: bit-identical trajectory end point.
    assert_eq!(seq_clock.now_s().to_bits(), par_clock.now_s().to_bits());
    // Trace spans: identical.
    assert_eq!(seq.trace.events, par.trace.events);
}

#[test]
fn pool_overlaps_real_work() {
    // 8 clients x 25ms of real work: sequential >= 200ms, 4 workers should
    // land well under that even on a loaded CI box.
    let mut seq = server(stub_fleet(8, 25), 1);
    let t0 = Instant::now();
    seq.run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward()).unwrap();
    let t_seq = t0.elapsed();

    let mut par = server(stub_fleet(8, 25), 4);
    let t0 = Instant::now();
    par.run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward()).unwrap();
    let t_par = t0.elapsed();

    assert!(
        t_par < t_seq,
        "pooled engine ({t_par:?}) must beat sequential ({t_seq:?})"
    );
}

#[test]
fn pooled_engine_survives_oom_clients() {
    let mut clients = stub_fleet(4, 0);
    let mut bad = StubClient::new(4, 0);
    bad.fail_with = Some(EmuError::GpuOom {
        device: "stub".into(),
        requested_mb: 8192,
        available_mb: 1024,
        capacity_mb: 4096,
    });
    clients.push(Box::new(bad));

    let mut s = server(clients, 3);
    let (_, h) = s
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .unwrap();
    for r in &h.rounds {
        assert_eq!(r.failures.len(), 1, "OOM client fails every round");
        assert_eq!(r.failures[0].client, 4);
        assert!(r.train_loss.is_finite());
    }
}

#[test]
fn pooled_engine_propagates_fatal_errors_and_returns_clients() {
    let mut clients = stub_fleet(3, 0);
    let mut bad = StubClient::new(3, 0);
    bad.fail_with = Some(EmuError::Lifecycle("stub runtime failure".into()));
    clients.push(Box::new(bad));

    let mut s = server(clients, 2);
    let err = s
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .unwrap_err();
    assert!(err.to_string().contains("client 3"), "{err}");
}

#[test]
fn pooled_engine_contains_fit_panics_instead_of_hanging() {
    // A panic inside a worker's fit must come back as a fit error (the
    // inline engine would propagate the panic; the pool must neither hang
    // waiting for a never-sent outcome nor kill the process).
    let mut clients = stub_fleet(3, 0);
    let mut bad = StubClient::new(3, 0);
    bad.panic_in_fit = true;
    clients.push(Box::new(bad));

    let mut s = server(clients, 2);
    let err = s
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .unwrap_err();
    assert!(err.to_string().contains("client 3"), "{err}");
    assert!(err.to_string().contains("panicked"), "{err}");
}

#[test]
fn robust_strategies_run_on_the_pooled_engine() {
    // TrimmedMean uses the bounded-buffer accumulator — the pooled engine
    // must feed it identically to the sequential one.
    let build = |workers| {
        let cfg = ServerConfig { rounds: 2, eval_every: 0, seed: 5, ..Default::default() };
        let s = ServerApp::new(
            cfg,
            HardwareProfile::paper_host(),
            Box::new(TrimmedMean::new(1)),
            Box::new(Sequential),
            stub_fleet(6, 0),
        );
        if workers > 1 { s.with_round_engine(workers, None) } else { s }
    };
    let (g1, _) = build(1)
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .unwrap();
    let (g2, _) = build(4)
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .unwrap();
    for (a, b) in g1.as_slice().iter().zip(g2.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn worker_pool_drop_joins_cleanly_mid_stream() {
    // Submit more work than we drain; dropping the pool must not hang.
    let pool = WorkerPool::spawn(2, None);
    let global = Arc::new(ParamVector::zeros(4));
    for i in 0..6 {
        pool.submit(bouquetfl::sched::FitTask {
            index: i,
            client: Box::new(StubClient::new(i as u32, 5)),
            global: Arc::clone(&global),
            cfg: FitConfig::default(),
            host: HardwareProfile::paper_host(),
            env_cfg: Default::default(),
        })
        .unwrap();
    }
    let _ = pool.recv().unwrap();
    drop(pool); // joins workers; outstanding tasks are discarded
}
