//! Concurrent round engine vs the sequential engine: same federation, same
//! seed, `workers = 1` vs `workers = 4` — every emulated observable
//! (schedule, clock, losses, aggregate bits) must be identical; only host
//! wall-clock may differ.  No PJRT artifacts needed: clients are stubs and
//! the server runs executor-less via `run_from`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use bouquetfl::emu::{FitReport, VirtualClock};
use bouquetfl::error::EmuError;
use bouquetfl::fl::{
    BouquetContext, ClientApp, ClientId, FedAvg, FitConfig, FitResult, ParamVector,
    Scenario, Selection, ServerApp, ServerConfig, TrimmedMean,
};
use bouquetfl::hardware::HardwareProfile;
use bouquetfl::sched::dynamics::{AvailabilityModel, AvailabilityTrace, FederationDynamics};
use bouquetfl::sched::{LimitedParallel, Sequential, WorkerPool};

const P: usize = 64;

/// Deterministic stub client: burns `work_ms` of real time (so pool
/// speedup is observable), advances the emulated clock exactly like a
/// restricted fit would, and returns params that depend only on its id.
struct StubClient {
    id: ClientId,
    profile: HardwareProfile,
    work_ms: u64,
    /// Emulated network comm seconds reported per fit.
    comm_s: f64,
    /// `Some(e)`: fail every fit with this error instead.
    fail_with: Option<EmuError>,
    /// Panic mid-fit instead of returning (worker containment test).
    panic_in_fit: bool,
}

impl StubClient {
    fn new(id: ClientId, work_ms: u64) -> Self {
        StubClient {
            id,
            profile: HardwareProfile::paper_host(),
            work_ms,
            comm_s: 0.0,
            fail_with: None,
            panic_in_fit: false,
        }
    }

    fn params(&self) -> ParamVector {
        ParamVector::from_vec(
            (0..P)
                .map(|j| ((self.id as usize * 31 + j) % 17) as f32 * 0.1)
                .collect(),
        )
    }
}

impl ClientApp for StubClient {
    fn id(&self) -> ClientId {
        self.id
    }

    fn profile(&self) -> &HardwareProfile {
        &self.profile
    }

    fn num_examples(&self) -> usize {
        10 + self.id as usize
    }

    fn fit(
        &mut self,
        _global: &ParamVector,
        cfg: &FitConfig,
        ctx: &mut BouquetContext<'_>,
    ) -> Result<FitResult, EmuError> {
        if self.panic_in_fit {
            panic!("stub fit panic (client {})", self.id);
        }
        if let Some(e) = &self.fail_with {
            return Err(e.clone());
        }
        std::thread::sleep(Duration::from_millis(self.work_ms));
        let emu = FitReport::synthetic(cfg.local_steps, cfg.batch, 1.0 + self.id as f64);
        // Advance emulated time the way a restricted fit does, increment
        // by increment — the pooled engine replays exactly this.
        ctx.clock.advance(emu.warmup_s);
        for _ in 0..emu.steps {
            ctx.clock.advance(emu.step_s);
        }
        Ok(FitResult {
            client: self.id,
            params: self.params(),
            num_examples: self.num_examples(),
            mean_loss: 1.0 / (1.0 + self.id as f32),
            emu,
            comm_s: self.comm_s,
        })
    }
}

fn server(clients: Vec<Box<dyn ClientApp>>, workers: usize) -> ServerApp {
    let cfg = ServerConfig {
        rounds: 3,
        selection: Selection::All,
        eval_every: 0,
        seed: 11,
        ..Default::default()
    };
    let s = ServerApp::new(
        cfg,
        HardwareProfile::paper_host(),
        Box::new(FedAvg),
        Box::new(Sequential),
        clients,
    );
    if workers > 1 {
        s.with_round_engine(workers, None)
    } else {
        s
    }
}

fn stub_fleet(n: u32, work_ms: u64) -> Vec<Box<dyn ClientApp>> {
    (0..n).map(|i| Box::new(StubClient::new(i, work_ms)) as Box<dyn ClientApp>).collect()
}

#[test]
fn pooled_round_is_bit_identical_to_sequential() {
    let init = ParamVector::zeros(P);

    let mut seq = server(stub_fleet(8, 0), 1);
    let mut seq_clock = VirtualClock::fast_forward();
    let (g1, h1) = seq.run_from(init.clone(), None, &mut seq_clock).unwrap();

    let mut par = server(stub_fleet(8, 0), 4);
    let mut par_clock = VirtualClock::fast_forward();
    let (g2, h2) = par.run_from(init, None, &mut par_clock).unwrap();

    // Aggregates: bit-identical.
    assert_eq!(g1.len(), g2.len());
    for (a, b) in g1.as_slice().iter().zip(g2.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits(), "aggregate drifted across engines");
    }
    // Emulated history: bit-identical rounds.
    assert_eq!(h1.rounds.len(), h2.rounds.len());
    for (r1, r2) in h1.rounds.iter().zip(&h2.rounds) {
        assert_eq!(r1.selected, r2.selected);
        assert_eq!(r1.train_loss.to_bits(), r2.train_loss.to_bits());
        assert_eq!(r1.emu_round_s.to_bits(), r2.emu_round_s.to_bits());
    }
    // Shared emulated clock: bit-identical trajectory end point.
    assert_eq!(seq_clock.now_s().to_bits(), par_clock.now_s().to_bits());
    // Trace spans: identical.
    assert_eq!(seq.trace.events, par.trace.events);
}

#[test]
fn pool_overlaps_real_work() {
    // 8 clients x 25ms of real work: sequential >= 200ms, 4 workers should
    // land well under that even on a loaded CI box.
    let mut seq = server(stub_fleet(8, 25), 1);
    let t0 = Instant::now();
    seq.run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward()).unwrap();
    let t_seq = t0.elapsed();

    let mut par = server(stub_fleet(8, 25), 4);
    let t0 = Instant::now();
    par.run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward()).unwrap();
    let t_par = t0.elapsed();

    assert!(
        t_par < t_seq,
        "pooled engine ({t_par:?}) must beat sequential ({t_seq:?})"
    );
}

#[test]
fn pooled_engine_survives_oom_clients() {
    let mut clients = stub_fleet(4, 0);
    let mut bad = StubClient::new(4, 0);
    bad.fail_with = Some(EmuError::GpuOom {
        device: "stub".into(),
        requested_mb: 8192,
        available_mb: 1024,
        capacity_mb: 4096,
    });
    clients.push(Box::new(bad));

    let mut s = server(clients, 3);
    let (_, h) = s
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .unwrap();
    for r in &h.rounds {
        assert_eq!(r.failures.len(), 1, "OOM client fails every round");
        assert_eq!(r.failures[0].client, 4);
        assert!(r.train_loss.is_finite());
    }
}

#[test]
fn pooled_engine_propagates_fatal_errors_and_returns_clients() {
    let mut clients = stub_fleet(3, 0);
    let mut bad = StubClient::new(3, 0);
    bad.fail_with = Some(EmuError::Lifecycle("stub runtime failure".into()));
    clients.push(Box::new(bad));

    let mut s = server(clients, 2);
    let err = s
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .unwrap_err();
    assert!(err.to_string().contains("client 3"), "{err}");
}

#[test]
fn pooled_engine_contains_fit_panics_instead_of_hanging() {
    // A panic inside a worker's fit must come back as a fit error (the
    // inline engine would propagate the panic; the pool must neither hang
    // waiting for a never-sent outcome nor kill the process).
    let mut clients = stub_fleet(3, 0);
    let mut bad = StubClient::new(3, 0);
    bad.panic_in_fit = true;
    clients.push(Box::new(bad));

    let mut s = server(clients, 2);
    let err = s
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .unwrap_err();
    assert!(err.to_string().contains("client 3"), "{err}");
    assert!(err.to_string().contains("panicked"), "{err}");
}

#[test]
fn robust_strategies_run_on_the_pooled_engine() {
    // TrimmedMean uses the bounded-buffer accumulator — the pooled engine
    // must feed it identically to the sequential one.
    let build = |workers| {
        let cfg = ServerConfig { rounds: 2, eval_every: 0, seed: 5, ..Default::default() };
        let s = ServerApp::new(
            cfg,
            HardwareProfile::paper_host(),
            Box::new(TrimmedMean::new(1)),
            Box::new(Sequential),
            stub_fleet(6, 0),
        );
        if workers > 1 { s.with_round_engine(workers, None) } else { s }
    };
    let (g1, _) = build(1)
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .unwrap();
    let (g2, _) = build(4)
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .unwrap();
    for (a, b) in g1.as_slice().iter().zip(g2.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

// ---------------------------------------------------------------------
// Federation dynamics suite: availability, churn, mid-round dropout and
// deadline rounds must preserve the engine's core invariant — same seed +
// same scenario => identical schedule/clock/aggregates for any --workers.
// ---------------------------------------------------------------------

/// Every emulated observable of two runs, for exact comparison.
fn run_observables(
    mut server: ServerApp,
) -> (ParamVector, bouquetfl::fl::History, f64, Vec<bouquetfl::sched::TraceEvent>) {
    let mut clock = VirtualClock::fast_forward();
    let (global, history) = server
        .run_from(ParamVector::zeros(P), None, &mut clock)
        .expect("dynamics run");
    let trace = std::mem::take(&mut server.trace);
    (global, history, clock.now_s(), trace.events)
}

fn assert_runs_identical(a: ServerApp, b: ServerApp) {
    let (g1, h1, clock1, t1) = run_observables(a);
    let (g2, h2, clock2, t2) = run_observables(b);
    for (x, y) in g1.as_slice().iter().zip(g2.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits(), "aggregate drifted");
    }
    assert_eq!(h1.rounds.len(), h2.rounds.len());
    for (r1, r2) in h1.rounds.iter().zip(&h2.rounds) {
        assert_eq!(r1.selected, r2.selected, "round {}", r1.round);
        assert_eq!(
            r1.train_loss.to_bits(),
            r2.train_loss.to_bits(),
            "round {}",
            r1.round
        );
        assert_eq!(
            r1.emu_round_s.to_bits(),
            r2.emu_round_s.to_bits(),
            "round {}",
            r1.round
        );
        assert_eq!(r1.failures.len(), r2.failures.len(), "round {}", r1.round);
        for (f1, f2) in r1.failures.iter().zip(&r2.failures) {
            assert_eq!(f1.client, f2.client);
            assert_eq!(f1.reason, f2.reason);
        }
    }
    assert_eq!(clock1.to_bits(), clock2.to_bits(), "shared clock drifted");
    assert_eq!(t1, t2, "trace spans drifted");
}

fn scenario_server(n: u32, workers: usize, scenario: &Scenario) -> ServerApp {
    server(stub_fleet(n, 0), workers).with_scenario(scenario)
}

#[test]
fn dynamics_inactive_scenario_is_bit_identical_to_no_scenario() {
    // A *non-static* scenario that never actually drops anyone (diurnal
    // with a 100% online fraction, no churn, open rounds) exercises the
    // whole dynamics code path — eligibility, gate, gate-built schedule —
    // and must reproduce today's engine output bit for bit.
    let sc = Scenario {
        name: "never-drops".into(),
        availability: AvailabilityModel::Diurnal { period_s: 600.0, online_fraction: 1.0 },
        join_prob: 0.0,
        leave_prob: 0.0,
        round_deadline_s: f64::INFINITY,
    };
    assert!(!sc.is_static(), "test needs the dynamic path");
    // Clients report nonzero comm so the claim covers network-attached
    // fleets: the scenario layer must not touch the replay clock.
    let fleet = || -> Vec<Box<dyn ClientApp>> {
        (0..8u32)
            .map(|i| {
                let mut c = StubClient::new(i, 0);
                c.comm_s = 0.25 * (i as f64 + 1.0);
                Box::new(c) as Box<dyn ClientApp>
            })
            .collect()
    };
    assert_runs_identical(server(fleet(), 1), server(fleet(), 1).with_scenario(&sc));
    // And the dynamic path itself is worker-count invariant.
    assert_runs_identical(
        server(fleet(), 1).with_scenario(&sc),
        server(fleet(), 4).with_scenario(&sc),
    );
}

#[test]
fn dynamics_drop_free_rounds_render_the_configured_scheduler() {
    // Under --parallel K the static engine packs LPT; a scenario that
    // never drops anyone must reproduce that schedule bit for bit — the
    // gate's FIFO packing is only rendered when a drop actually happened.
    let sc = Scenario {
        name: "never-drops".into(),
        availability: AvailabilityModel::Diurnal { period_s: 600.0, online_fraction: 1.0 },
        join_prob: 0.0,
        leave_prob: 0.0,
        round_deadline_s: f64::INFINITY,
    };
    let mk = |scenario: Option<&Scenario>| {
        let cfg = ServerConfig {
            rounds: 3,
            selection: Selection::All,
            eval_every: 0,
            seed: 11,
            ..Default::default()
        };
        let mut s = ServerApp::new(
            cfg,
            HardwareProfile::paper_host(),
            Box::new(FedAvg),
            Box::new(LimitedParallel::new(3)),
            stub_fleet(8, 0),
        );
        if let Some(sc) = scenario {
            s = s.with_scenario(sc);
        }
        s
    };
    assert_runs_identical(mk(None), mk(Some(&sc)));
}

#[test]
fn dynamics_deadline_drops_stragglers_identically_across_engines() {
    // Stub durations are 1+id seconds; sequential packing ends at
    // 1,3,6,10,15,... With a 10s deadline clients 0..3 finish in time and
    // 4..7 are late, every round — deterministic by construction.
    let sc = Scenario {
        name: "deadline-10".into(),
        availability: AvailabilityModel::AlwaysOn,
        join_prob: 0.0,
        leave_prob: 0.0,
        round_deadline_s: 10.0,
    };
    let (g1, h1, _, _) = run_observables(scenario_server(8, 1, &sc));
    for r in &h1.rounds {
        assert_eq!(r.selected.len(), 8);
        let late: Vec<u32> = r.failures.iter().map(|f| f.client).collect();
        assert_eq!(late, vec![4, 5, 6, 7], "round {}", r.round);
        assert!(
            r.failures.iter().all(|f| f.reason.starts_with("deadline:")),
            "round {}: {:?}",
            r.round,
            r.failures
        );
        assert_eq!(r.emu_round_s.to_bits(), 10.0f64.to_bits());
        assert!(r.train_loss.is_finite());
    }
    // Dropped clients leave no residue: the aggregate equals a plain
    // federation of only the four finishers (same ids, same fold order).
    let (g_ref, _, _, _) = run_observables(server(stub_fleet(4, 0), 1));
    for (a, b) in g1.as_slice().iter().zip(g_ref.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits(), "late clients leaked into the mean");
    }
    // Worker-count invariance with drops in every round.
    assert_runs_identical(scenario_server(8, 1, &sc), scenario_server(8, 4, &sc));
}

#[test]
fn dynamics_mid_fit_dropout_with_injected_trace_is_identical_across_engines() {
    // Client 5 goes offline at emulated t = 3.0 and never returns.  In
    // round 0 it is online at selection time (t = 0) but its fit window
    // [15, 21) crosses the boundary -> mid-round dropout; from round 1 on
    // it is offline at selection time -> never selected again.
    let build = |workers: usize| {
        let mut dynamics = FederationDynamics::new(
            11,
            8,
            &AvailabilityModel::AlwaysOn,
            0.0,
            0.0,
            f64::INFINITY,
            1,
        );
        dynamics.set_trace(5, AvailabilityTrace::from_toggles(true, vec![3.0]));
        server(stub_fleet(8, 0), workers).with_dynamics(dynamics)
    };
    let (_, h, _, _) = run_observables(build(1));
    assert_eq!(h.rounds[0].selected.len(), 8);
    assert_eq!(h.rounds[0].failures.len(), 1);
    assert_eq!(h.rounds[0].failures[0].client, 5);
    assert!(
        h.rounds[0].failures[0].reason.starts_with("dropout:"),
        "{}",
        h.rounds[0].failures[0].reason
    );
    for r in &h.rounds[1..] {
        assert_eq!(r.selected, vec![0, 1, 2, 3, 4, 6, 7], "round {}", r.round);
        assert!(r.failures.is_empty(), "round {}", r.round);
    }
    assert_runs_identical(build(1), build(4));
}

#[test]
fn dynamics_churny_federation_is_identical_across_engines() {
    // The full stack at once: membership churn + battery availability +
    // a deadline.  Everything stays deterministic per seed and
    // bit-identical across worker counts.
    let sc = Scenario {
        name: "stress".into(),
        availability: AvailabilityModel::Battery {
            drain_s: 25.0,
            recharge_s: 10.0,
            jitter: 0.3,
        },
        join_prob: 0.5,
        leave_prob: 0.4,
        round_deadline_s: 14.0,
    };
    let mk = |workers| {
        let cfg = ServerConfig {
            rounds: 8,
            selection: Selection::All,
            eval_every: 0,
            seed: 11,
            ..Default::default()
        };
        let s = ServerApp::new(
            cfg,
            HardwareProfile::paper_host(),
            Box::new(FedAvg),
            Box::new(Sequential),
            stub_fleet(8, 0),
        )
        .with_scenario(&sc);
        if workers > 1 {
            s.with_round_engine(workers, None)
        } else {
            s
        }
    };
    let (_, h, _, _) = run_observables(mk(1));
    // With leave_prob 0.4 over 8 rounds x 8 clients, some round must have
    // seen churn or drops (deterministic per seed; sanity, not luck).
    let dynamic_activity = h.rounds.iter().any(|r| {
        r.selected.len() < 8 || !r.failures.is_empty()
    });
    assert!(dynamic_activity, "scenario produced no dynamics at all");
    assert_runs_identical(mk(1), mk(4));
}

#[test]
fn dynamics_all_late_round_costs_the_deadline_and_is_not_fatal() {
    // Every fit (1..4s) misses a 0.5s deadline: the round held open until
    // the deadline is recorded as exactly that long, contributes nothing,
    // and the federation carries on.
    let sc = Scenario {
        name: "impossible-deadline".into(),
        availability: AvailabilityModel::AlwaysOn,
        join_prob: 0.0,
        leave_prob: 0.0,
        round_deadline_s: 0.5,
    };
    let (_, h, _, _) = run_observables(scenario_server(4, 1, &sc));
    for r in &h.rounds {
        assert_eq!(r.selected.len(), 4);
        assert_eq!(r.failures.len(), 4);
        assert!(r.train_loss.is_nan());
        assert_eq!(r.emu_round_s.to_bits(), 0.5f64.to_bits());
    }
}

#[test]
fn dynamics_all_dropout_round_advances_to_the_last_disconnection() {
    // Both clients are online at round start but disconnect at t = 0.5,
    // mid-fit, and return at t = 100.  The all-dropout round must advance
    // the scenario timeline (to 0.5 — the last observed disconnection),
    // the next round fast-forwards past the offline gap, and the
    // federation then recovers: no frozen identical-round replay.
    let build = || {
        let mut dynamics = FederationDynamics::new(
            11,
            2,
            &AvailabilityModel::AlwaysOn,
            0.0,
            0.0,
            f64::INFINITY,
            1,
        );
        for i in 0..2 {
            dynamics.set_trace(i, AvailabilityTrace::from_toggles(true, vec![0.5, 100.0]));
        }
        server(stub_fleet(2, 0), 1).with_dynamics(dynamics)
    };
    let (_, h, _, _) = run_observables(build());
    // Round 0: everyone drops mid-fit.
    assert_eq!(h.rounds[0].failures.len(), 2);
    assert!(h.rounds[0]
        .failures
        .iter()
        .all(|f| f.reason.starts_with("dropout:")));
    assert_eq!(h.rounds[0].emu_round_s.to_bits(), 0.5f64.to_bits());
    // Round 1: nobody online at t = 0.5 -> skipped, waiting out the gap.
    assert!(h.rounds[1].selected.is_empty());
    assert_eq!(h.rounds[1].emu_round_s.to_bits(), 99.5f64.to_bits());
    // Round 2: back online, training resumes.
    assert_eq!(h.rounds[2].selected.len(), 2);
    assert!(h.rounds[2].failures.is_empty());
    assert!(h.rounds[2].train_loss.is_finite());
}

#[test]
fn dynamics_does_not_mask_non_dynamic_empty_rounds() {
    // A round that ends empty because every client OOM'd (the gate dropped
    // nobody) must fail exactly as it would on the static engine — the
    // scenario only excuses emptiness it caused.
    let sc = Scenario {
        name: "deadline-only".into(),
        availability: AvailabilityModel::AlwaysOn,
        join_prob: 0.0,
        leave_prob: 0.0,
        round_deadline_s: 1000.0,
    };
    let mut clients: Vec<Box<dyn ClientApp>> = Vec::new();
    for i in 0..3 {
        let mut c = StubClient::new(i, 0);
        c.fail_with = Some(EmuError::GpuOom {
            device: "stub".into(),
            requested_mb: 8192,
            available_mb: 1024,
            capacity_mb: 4096,
        });
        clients.push(Box::new(c));
    }
    let mut s = server(clients, 1).with_scenario(&sc);
    let err = s
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .unwrap_err();
    assert!(err.to_string().contains("3 selected clients failed"), "{err}");
}

#[test]
fn dynamics_all_offline_round_fast_forwards_to_the_next_online_member() {
    // Everyone is offline until t = 100: round 0 is recorded as a skipped
    // round whose emulated length is the wait, and round 1 proceeds.
    let mut dynamics = FederationDynamics::new(
        11,
        4,
        &AvailabilityModel::AlwaysOn,
        0.0,
        0.0,
        f64::INFINITY,
        1,
    );
    for i in 0..4 {
        dynamics.set_trace(i, AvailabilityTrace::from_toggles(false, vec![100.0]));
    }
    let mut s = server(stub_fleet(4, 0), 1).with_dynamics(dynamics);
    let mut clock = VirtualClock::fast_forward();
    let (_, h) = s.run_from(ParamVector::zeros(P), None, &mut clock).unwrap();
    assert!(h.rounds[0].selected.is_empty());
    assert!(h.rounds[0].train_loss.is_nan());
    assert_eq!(h.rounds[0].emu_round_s.to_bits(), 100.0f64.to_bits());
    assert_eq!(h.rounds[1].selected.len(), 4);
    assert!(h.rounds[1].train_loss.is_finite());
    assert!(clock.now_s() >= 100.0);
}

#[test]
fn worker_pool_drop_joins_cleanly_mid_stream() {
    // Submit more work than we drain; dropping the pool must not hang.
    let pool = WorkerPool::spawn(2, None);
    let global = Arc::new(ParamVector::zeros(4));
    for i in 0..6 {
        pool.submit(bouquetfl::sched::FitTask {
            index: i,
            client: Box::new(StubClient::new(i as u32, 5)),
            global: Arc::clone(&global),
            cfg: FitConfig::default(),
            host: HardwareProfile::paper_host(),
            env_cfg: Default::default(),
            fold: None,
        })
        .unwrap();
    }
    let _ = pool.recv().unwrap();
    drop(pool); // joins workers; outstanding tasks are discarded
}
