//! Integration tests of the full FL pipeline (ServerApp round loop +
//! BouquetFL restriction + strategies) with real PJRT execution.

use bouquetfl::data::PartitionScheme;
use bouquetfl::fl::launcher::{launch, HardwareSource, LaunchOptions};
use bouquetfl::fl::Selection;
use bouquetfl::hardware::SamplerConfig;

fn tiny_opts() -> LaunchOptions {
    LaunchOptions {
        clients: 3,
        rounds: 2,
        samples_per_client: 48,
        eval_samples: 128,
        batch: 16,
        local_steps: 2,
        lr: 0.02,
        eval_every: 2,
        seed: 7,
        hardware: HardwareSource::Manual(vec![
            "gtx-1060".into(),
            "rtx-3060".into(),
            "gtx-1650".into(),
        ]),
        ..Default::default()
    }
}

#[test]
fn federation_trains_and_records_history() {
    let outcome = launch(&tiny_opts()).expect("federation must run");
    assert_eq!(outcome.history.rounds.len(), 2);
    assert_eq!(outcome.profiles.len(), 3);
    let first = outcome.history.rounds[0].train_loss;
    let last = outcome.history.final_train_loss().unwrap();
    assert!(first.is_finite() && last.is_finite());
    assert!(last < first, "loss should drop: {first} -> {last}");
    // Eval ran on round 2.
    assert!(outcome.history.rounds[1].eval_loss.is_some());
    // Emulated round time reflects heterogeneous hardware (> 0).
    assert!(outcome.history.rounds[0].emu_round_s > 0.0);
    assert_eq!(outcome.global.len(), 549_290);
}

#[test]
fn all_strategies_run_one_round() {
    for strategy in ["fedavg", "fedprox", "fedavgm", "fedadam", "trimmed-mean", "krum"] {
        let opts = LaunchOptions {
            rounds: 1,
            strategy: strategy.into(),
            ..tiny_opts()
        };
        let outcome =
            launch(&opts).unwrap_or_else(|e| panic!("strategy {strategy} failed: {e}"));
        assert!(
            outcome.history.rounds[0].train_loss.is_finite(),
            "{strategy} produced non-finite loss"
        );
    }
}

#[test]
fn sampler_hardware_source_runs() {
    let opts = LaunchOptions {
        clients: 4,
        rounds: 1,
        hardware: HardwareSource::Sampler(SamplerConfig::default()),
        ..tiny_opts()
    };
    let outcome = launch(&opts).unwrap();
    assert_eq!(outcome.profiles.len(), 4);
    // All sampled profiles must be feasible on the paper host.
    for p in &outcome.profiles {
        assert!(p.gpu.vram_gib <= 12.0, "{}", p.gpu.slug);
        assert!(p.cpu.cores <= 8, "{}", p.cpu.slug);
        assert!(p.ram.gib <= 32, "{}", p.cpu.slug);
    }
}

#[test]
fn slow_hardware_means_longer_emulated_rounds() {
    // Same data/seed, two federations: all-slow vs all-fast GPUs.
    let slow = launch(&LaunchOptions {
        hardware: HardwareSource::Manual(vec!["gtx-1050-ti".into()]),
        rounds: 1,
        ..tiny_opts()
    })
    .unwrap();
    let fast = launch(&LaunchOptions {
        hardware: HardwareSource::Manual(vec!["rtx-3080".into()]),
        rounds: 1,
        ..tiny_opts()
    })
    .unwrap();
    let ts = slow.history.rounds[0].emu_round_s;
    let tf = fast.history.rounds[0].emu_round_s;
    assert!(
        ts > 2.0 * tf,
        "GTX 1050 Ti federation ({ts:.3}s) must be much slower than RTX 3080 ({tf:.3}s)"
    );
}

#[test]
fn partition_schemes_all_run() {
    for scheme in [
        PartitionScheme::Iid,
        PartitionScheme::Dirichlet { alpha: 0.2 },
        PartitionScheme::Shards { labels_per_client: 2 },
    ] {
        let opts = LaunchOptions { partition: scheme, rounds: 1, ..tiny_opts() };
        assert!(launch(&opts).is_ok(), "{scheme:?}");
    }
}

#[test]
fn client_fraction_selection_subsets_clients() {
    let opts = LaunchOptions {
        clients: 4,
        selection: Selection::Fraction(0.5),
        rounds: 2,
        ..tiny_opts()
    };
    let outcome = launch(&opts).unwrap();
    for r in &outcome.history.rounds {
        assert_eq!(r.selected.len(), 2);
    }
}

#[test]
fn parallel_scheduler_shrinks_round_wallclock() {
    let seq = launch(&LaunchOptions { max_parallel: 1, rounds: 1, ..tiny_opts() }).unwrap();
    let par = launch(&LaunchOptions { max_parallel: 3, rounds: 1, ..tiny_opts() }).unwrap();
    let ts = seq.history.rounds[0].emu_round_s;
    let tp = par.history.rounds[0].emu_round_s;
    assert!(tp < ts, "parallel {tp} must beat sequential {ts}");
    // ...but not below the slowest client (makespan lower bound).
    assert!(tp * 3.5 > ts, "parallel speedup bounded by the straggler");
}

#[test]
fn network_model_adds_comm_time() {
    let no_net = launch(&LaunchOptions { network: false, rounds: 1, ..tiny_opts() }).unwrap();
    let net = launch(&LaunchOptions { network: true, rounds: 1, ..tiny_opts() }).unwrap();
    assert!(
        net.history.rounds[0].emu_round_s > no_net.history.rounds[0].emu_round_s,
        "network transfers must lengthen the round"
    );
}

#[test]
fn infeasible_manual_hardware_is_rejected() {
    let opts = LaunchOptions {
        hardware: HardwareSource::Manual(vec!["rtx-4090".into()]),
        ..tiny_opts()
    };
    assert!(launch(&opts).is_err(), "a 4090 cannot be emulated on the 4070S host");
}
