//! End-to-end runtime tests: the AOT artifacts load, compile and execute
//! correctly through the PJRT CPU client — real numerics, no Python.
//!
//! Requires `make artifacts` to have produced `artifacts/` at the repo root
//! (the Makefile's `test` target guarantees this).

use std::sync::{Mutex, MutexGuard, OnceLock};

use bouquetfl::data::{generate, SyntheticConfig};
use bouquetfl::fl::ParamVector;
use bouquetfl::modelcost::CNN_NUM_PARAMS;
use bouquetfl::runtime::ModelExecutor;

/// `PjRtClient` holds `Rc`s and is not `Send`; sharing one executor across
/// test threads is still sound because every access goes through a single
/// `Mutex` and no reference-counted handle ever escapes the guard (the
/// executor API returns plain `ParamVector`/`f32` data).
struct SendExec(ModelExecutor);
// SAFETY: see above — exclusive access is enforced by the Mutex below.
unsafe impl Send for SendExec {}

/// One shared executor across all tests (one PJRT client, compile once).
fn executor() -> MutexGuard<'static, SendExec> {
    static EXEC: OnceLock<Mutex<SendExec>> = OnceLock::new();
    EXEC.get_or_init(|| {
        Mutex::new(SendExec(ModelExecutor::new("artifacts").expect(
            "artifacts/ missing or invalid — run `make artifacts` before `cargo test`",
        )))
    })
    .lock()
    .unwrap_or_else(|e| e.into_inner())
}

fn batch(n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let d = generate(&SyntheticConfig { seed, ..Default::default() }, n);
    (d.images, d.labels)
}

#[test]
fn init_params_deterministic_and_sized() {
    let ex = &mut executor().0;
    let a = ex.init_params(7).unwrap();
    let b = ex.init_params(7).unwrap();
    let c = ex.init_params(8).unwrap();
    assert_eq!(a.len(), CNN_NUM_PARAMS as usize);
    assert_eq!(a, b, "same seed, same params");
    assert_ne!(a, c, "different seed, different params");
    assert!(a.as_slice().iter().all(|x| x.is_finite()));
}

#[test]
fn train_step_reduces_loss_on_real_data() {
    let ex = &mut executor().0;
    let params = ex.init_params(1).unwrap();
    let (x, y) = batch(32, 11);
    let mut p = params;
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..20 {
        let (next, loss) = ex.train_step(&p, &x, &y, 0.02, 32).unwrap();
        p = next;
        first.get_or_insert(loss);
        last = loss;
    }
    let first = first.unwrap();
    assert!(
        last < 0.5 * first,
        "loss must halve in 20 steps: {first} -> {last}"
    );
}

#[test]
fn train_batches_b16_and_b32_both_work() {
    let ex = &mut executor().0;
    let params = ex.init_params(2).unwrap();
    for b in ex.train_batches() {
        let (x, y) = batch(b as usize, 100 + b as u64);
        let (next, loss) = ex.train_step(&params, &x, &y, 0.01, b).unwrap();
        assert_eq!(next.len(), params.len());
        assert!(loss.is_finite() && loss > 0.0, "b={b}: loss {loss}");
    }
}

#[test]
fn fused_scan_matches_sequential_steps() {
    let ex = &mut executor().0;
    let params = ex.init_params(3).unwrap();
    let k = 4u32;
    let b = 32u32;
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut batches = Vec::new();
    for i in 0..k {
        let (x, y) = batch(b as usize, 200 + i as u64);
        xs.extend_from_slice(&x);
        ys.extend_from_slice(&y);
        batches.push((x, y));
    }

    let (fused, fused_loss) = ex.train_steps_fused(&params, &xs, &ys, 0.02, k, b).unwrap();

    let mut seq = params.clone();
    let mut losses = Vec::new();
    for (x, y) in &batches {
        let (next, loss) = ex.train_step(&seq, x, y, 0.02, b).unwrap();
        seq = next;
        losses.push(loss);
    }
    let seq_mean = losses.iter().sum::<f32>() / k as f32;

    // Same computation, same artifacts; tolerances cover non-determinism in
    // XLA reductions.
    let max_diff = fused
        .as_slice()
        .iter()
        .zip(seq.as_slice())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 5e-4, "fused vs sequential params differ by {max_diff}");
    assert!((fused_loss - seq_mean).abs() < 1e-3, "{fused_loss} vs {seq_mean}");
}

#[test]
fn prox_step_with_zero_mu_equals_plain_step() {
    let ex = &mut executor().0;
    let params = ex.init_params(4).unwrap();
    let (x, y) = batch(32, 300);
    let (plain, l1) = ex.train_step(&params, &x, &y, 0.05, 32).unwrap();
    let (prox, l2) = ex
        .train_step_prox(&params, &params, &x, &y, 0.05, 0.0, 32)
        .unwrap();
    let max_diff = plain
        .as_slice()
        .iter()
        .zip(prox.as_slice())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-5, "{max_diff}");
    assert!((l1 - l2).abs() < 1e-5);
}

#[test]
fn prox_step_large_mu_shrinks_distance_to_global() {
    let ex = &mut executor().0;
    let global = ex.init_params(5).unwrap();
    // Perturbed local params.
    let mut local = global.clone();
    for (i, v) in local.as_mut_slice().iter_mut().enumerate() {
        *v += 0.05 * ((i % 17) as f32 - 8.0) / 8.0;
    }
    let before = local.sub(&global).l2_norm();
    let (x, y) = batch(32, 400);
    let (after_p, _) = ex
        .train_step_prox(&local, &global, &x, &y, 0.01, 50.0, 32)
        .unwrap();
    let after = after_p.sub(&global).l2_norm();
    assert!(after < before, "{after} !< {before}");
}

#[test]
fn eval_counts_correct_predictions() {
    let ex = &mut executor().0;
    let params = ex.init_params(6).unwrap();
    let b = ex.eval_batch_size().expect("eval artifact");
    let (x, y) = batch(b as usize, 500);
    let (loss, correct) = ex.eval_batch(&params, &x, &y, b).unwrap();
    assert!(loss > 0.0 && loss.is_finite());
    assert!((0.0..=b as f32).contains(&correct));
}

#[test]
fn trained_model_beats_chance_on_holdout() {
    let ex = &mut executor().0;
    let mut p = ex.init_params(9).unwrap();
    // Train on 6 different batches, 5 passes.
    let batches: Vec<_> = (0..6).map(|i| batch(32, 600 + i)).collect();
    for _ in 0..5 {
        for (x, y) in &batches {
            let (next, _) = ex.train_step(&p, x, y, 0.02, 32).unwrap();
            p = next;
        }
    }
    let b = ex.eval_batch_size().unwrap();
    let (x, y) = batch(b as usize, 999); // unseen samples, same prototypes
    let (_, correct) = ex.eval_batch(&p, &x, &y, b).unwrap();
    let acc = correct / b as f32;
    assert!(acc > 0.3, "accuracy {acc} is not above 10-class chance");
}

#[test]
fn hlo_aggregate_matches_rust_weighted_sum() {
    let ex = &mut executor().0;
    let n = ex.num_params();
    let mk = |seed: u64| {
        let mut v = vec![0f32; n];
        let mut state = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        for x in v.iter_mut() {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            *x = ((state >> 40) as f32 / (1u64 << 24) as f32) - 0.5;
        }
        ParamVector::from_vec(v)
    };
    for k in ex.runtime().manifest.agg_ks() {
        let updates: Vec<ParamVector> = (0..k as u64).map(mk).collect();
        let mut weights: Vec<f32> = (1..=k).map(|i| i as f32).collect();
        let total: f32 = weights.iter().sum();
        weights.iter_mut().for_each(|w| *w /= total);

        let hlo = ex.aggregate(&updates, &weights).unwrap();
        let rust = ParamVector::weighted_sum(&updates, &weights);
        let max_diff = hlo
            .as_slice()
            .iter()
            .zip(rust.as_slice())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-5, "k={k}: HLO vs Rust differ by {max_diff}");
    }
}

#[test]
fn aggregate_falls_back_for_unmatched_fan_in() {
    let ex = &mut executor().0;
    let n = ex.num_params();
    // k=3 has no compiled artifact (AGG_KS = 4, 8, 16).
    let updates: Vec<ParamVector> = (0..3)
        .map(|i| ParamVector::from_vec(vec![i as f32; n]))
        .collect();
    let out = ex.aggregate(&updates, &[0.2, 0.3, 0.5]).unwrap();
    // 0*0.2 + 1*0.3 + 2*0.5 = 1.3
    assert!((out.as_slice()[0] - 1.3).abs() < 1e-6);
}

#[test]
fn shape_validation_errors_are_clean() {
    let ex = &mut executor().0;
    let params = ex.init_params(10).unwrap();
    let (x, y) = batch(16, 700);
    // Wrong batch artifact: b=33 doesn't exist.
    assert!(ex.train_step(&params, &x, &y, 0.01, 33).is_err());
    // Wrong param length.
    let bad = ParamVector::zeros(10);
    assert!(ex.train_step(&bad, &x, &y, 0.01, 16).is_err());
    // Wrong x/y sizes.
    assert!(ex.train_step(&params, &x[..100], &y, 0.01, 16).is_err());
}

#[test]
fn warm_up_compiles_every_artifact() {
    let ex = &mut executor().0;
    ex.warm_up().unwrap();
    let n_artifacts = ex.runtime().manifest.artifacts.len();
    assert_eq!(ex.runtime().compiled_count(), n_artifacts);
    assert!(n_artifacts >= 8);
}
