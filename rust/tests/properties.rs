//! Property-based tests (via `util::prop`, our proptest stand-in) on the
//! coordinator's invariants: timing monotonicity, MPS quantisation bounds,
//! VRAM accounting, partitioning, scheduling, aggregation linearity,
//! correlation bounds.

use bouquetfl::analysis::correlation::{kendall_tau_b, pearson, spearman};
use bouquetfl::data::{generate, partition, PartitionScheme, SyntheticConfig};
use bouquetfl::emu::{FitReport, GpuTimingModel, MpsPartition, Optimizer, VramAllocator};
use bouquetfl::durable::{self, DurableOptions};
use bouquetfl::fl::{
    AccOutput, AggAccumulator, ClientManager, Experiment, ExperimentReport, FitResult,
    ParamVector, Selection, StreamingMean, TreeMean, SCENARIO_PRESETS,
};
use bouquetfl::hardware::GPU_DB;
use bouquetfl::modelcost::resnet18_cifar;
use bouquetfl::hardware::sampler::HardwareSampler;
use bouquetfl::sched::dynamics::{
    AvailabilityModel, AvailabilityTrace, FederationDynamics, GateVerdict, RoundGate,
};
use bouquetfl::sched::pool::FitOutcomeSlim;
use bouquetfl::sched::{DeadlineSequential, LimitedParallel, ReorderBuffer, Scheduler, Sequential};
use bouquetfl::util::prop::{assert_close, assert_that, check};
use bouquetfl::util::rng::Pcg;

#[test]
fn prop_step_time_monotone_in_batch() {
    let w = resnet18_cifar();
    check(60, |rng| {
        let gpu = rng.choice(GPU_DB);
        let b1 = rng.range_i64(1, 256) as u32;
        let b2 = b1 + rng.range_i64(1, 256) as u32;
        let m = GpuTimingModel::new(gpu);
        let t1 = m.step_seconds(&w, b1, Optimizer::Sgd);
        let t2 = m.step_seconds(&w, b2, Optimizer::Sgd);
        assert_that(t2 > t1, || {
            format!("{}: t({b2})={t2} !> t({b1})={t1}", gpu.slug)
        })
    });
}

#[test]
fn prop_step_time_monotone_in_share() {
    let w = resnet18_cifar();
    check(60, |rng| {
        let gpu = rng.choice(GPU_DB);
        let s1 = rng.range_f64(0.05, 0.95);
        let s2 = (s1 + rng.range_f64(0.01, 1.0)).min(1.0);
        let t1 = GpuTimingModel::with_share(gpu, s1).step_seconds(&w, 32, Optimizer::Sgd);
        let t2 = GpuTimingModel::with_share(gpu, s2).step_seconds(&w, 32, Optimizer::Sgd);
        assert_that(t2 <= t1, || {
            format!("{}: share {s2} slower than {s1} ({t2} vs {t1})", gpu.slug)
        })
    });
}

#[test]
fn prop_mps_share_within_one_sm_of_request() {
    check(100, |rng| {
        let gpu = rng.choice(GPU_DB);
        let pct = rng.range_f64(0.5, 100.0);
        let p = MpsPartition::new(gpu, pct).map_err(|e| e.to_string())?;
        let requested = pct / 100.0;
        let granted = p.effective_share();
        let sm = 1.0 / gpu.sm_count() as f64;
        assert_that(granted >= requested - 1e-12, || {
            format!("{}: granted {granted} < requested {requested}", gpu.slug)
        })?;
        assert_that(granted <= requested + sm + 1e-12, || {
            format!("{}: granted {granted} over-provisioned vs {requested}", gpu.slug)
        })
    });
}

#[test]
fn prop_vram_accounting_balanced() {
    check(50, |rng| {
        let gpu = rng.choice(GPU_DB);
        let mut alloc = VramAllocator::new(gpu);
        let mut live = Vec::new();
        let mut expected: u64 = 0;
        for _ in 0..rng.range_i64(1, 60) {
            if rng.f64() < 0.6 || live.is_empty() {
                let bytes = rng.range_i64(1, 64 * 1024 * 1024) as u64;
                if let Ok(id) = alloc.alloc("x", bytes) {
                    live.push((id, bytes));
                    expected += bytes;
                }
            } else {
                let i = rng.below(live.len());
                let (id, bytes) = live.swap_remove(i);
                alloc.free(id);
                expected -= bytes;
            }
            assert_that(alloc.allocated() == expected, || {
                format!("accounting drift: {} vs {}", alloc.allocated(), expected)
            })?;
            assert_that(alloc.allocated() <= alloc.capacity(), || {
                "allocated beyond capacity".to_string()
            })?;
            assert_that(alloc.peak() >= alloc.allocated(), || {
                "peak below current".to_string()
            })?;
        }
        Ok(())
    });
}

#[test]
fn prop_partition_is_exact_for_all_schemes() {
    check(30, |rng| {
        let n = rng.range_i64(50, 400) as usize;
        let clients = rng.range_i64(2, 20) as usize;
        let data = generate(
            &SyntheticConfig { seed: rng.next_u64(), ..Default::default() },
            n,
        );
        let scheme = match rng.below(3) {
            0 => PartitionScheme::Iid,
            1 => PartitionScheme::Dirichlet { alpha: rng.range_f64(0.05, 10.0) },
            _ => PartitionScheme::Shards {
                labels_per_client: rng.range_i64(1, 4) as usize,
            },
        };
        let parts = partition(&data, clients, scheme, rng.next_u64());
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        all.sort();
        assert_that(all == (0..n).collect::<Vec<_>>(), || {
            format!("{scheme:?}: not an exact partition")
        })?;
        assert_that(parts.iter().all(|p| !p.is_empty()), || {
            format!("{scheme:?}: empty client partition")
        })
    });
}

#[test]
fn prop_scheduler_invariants() {
    check(80, |rng| {
        let n = rng.range_i64(1, 30) as usize;
        let durations: Vec<(u32, f64)> = (0..n)
            .map(|i| (i as u32, rng.range_f64(0.01, 10.0)))
            .collect();
        let seq = Sequential.schedule(&durations);
        let total: f64 = durations.iter().map(|(_, d)| d).sum();
        let longest = durations.iter().map(|(_, d)| *d).fold(0.0, f64::max);
        assert_close(seq.round_s, total, 1e-9)?;

        let slots = rng.range_i64(1, 8) as usize;
        let par = LimitedParallel::new(slots).schedule(&durations);
        assert_that(par.round_s <= seq.round_s + 1e-9, || {
            "parallel slower than sequential".to_string()
        })?;
        assert_that(par.round_s >= longest - 1e-9, || {
            format!("makespan {} below longest job {longest}", par.round_s)
        })?;
        assert_that(par.round_s >= total / slots as f64 - 1e-9, || {
            "makespan below work/slots bound".to_string()
        })?;
        assert_that(
            par.to_trace("t").max_concurrency() <= slots,
            || "concurrency cap violated".to_string(),
        )
    });
}

#[test]
fn prop_schedules_agree_across_policies() {
    // Sequential, LimitedParallel(1) and LimitedParallel(k) must agree on
    // the invariants the round engine relies on: same client set, same
    // per-client span lengths, non-overlap per slot (max concurrency), and
    // completion_order a permutation of the scheduled clients.
    check(60, |rng| {
        let n = rng.range_i64(1, 25) as usize;
        let durations: Vec<(u32, f64)> = (0..n)
            .map(|i| (i as u32, rng.range_f64(0.01, 5.0)))
            .collect();
        let seq = Sequential.schedule(&durations);
        let par1 = LimitedParallel::new(1).schedule(&durations);
        assert_close(seq.round_s, par1.round_s, 1e-9)?;
        assert_that(seq.to_trace("s").is_serial(), || {
            "sequential spans overlap".to_string()
        })?;
        assert_that(par1.to_trace("p1").is_serial(), || {
            "one-slot parallel spans overlap".to_string()
        })?;

        let slots = rng.range_i64(1, 6) as usize;
        let par = LimitedParallel::new(slots).schedule(&durations);
        for sched in [&seq, &par1, &par] {
            // Span length == client duration, for every policy.
            for &(c, s, e) in &sched.spans {
                let d = durations.iter().find(|&&(id, _)| id == c).unwrap().1;
                assert_close(e - s, d, 1e-9)?;
            }
            // Completion order is a permutation of the scheduled clients.
            let mut order = sched.completion_order();
            order.sort();
            assert_that(
                order == (0..n as u32).collect::<Vec<_>>(),
                || "completion_order not a permutation".to_string(),
            )?;
        }
        assert_that(
            par.to_trace("p").max_concurrency() <= slots,
            || "per-slot overlap: concurrency above slot count".to_string(),
        )
    });
}

#[test]
fn prop_streaming_fedavg_matches_batch_fedavg() {
    // The round engine's streaming mean (O(P) memory) must agree with the
    // materialise-everything batch path to 1e-6 on random param vectors.
    check(40, |rng| {
        let p = rng.range_i64(1, 600) as usize;
        let k = rng.range_i64(1, 24) as usize;
        let examples: Vec<usize> =
            (0..k).map(|_| rng.range_i64(1, 500) as usize).collect();
        let vectors: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..p).map(|_| rng.f32() * 2.0 - 1.0).collect())
            .collect();

        let mut acc = StreamingMean::new(p);
        for (c, v) in vectors.iter().enumerate() {
            acc.push(FitResult {
                client: c as u32,
                params: ParamVector::from_vec(v.clone()),
                num_examples: examples[c],
                mean_loss: 0.0,
                emu: FitReport::synthetic(1, 1, 0.0),
                comm_s: 0.0,
            })
            .map_err(|e| e.to_string())?;
            assert_that(acc.buffered_updates() == 0, || {
                "streaming accumulator buffered an update".to_string()
            })?;
        }
        let streamed = match Box::new(acc).finish().map_err(|e| e.to_string())? {
            AccOutput::Mean(m) => m.params,
            AccOutput::Buffered(_) => return Err("expected Mean output".into()),
        };

        let total: usize = examples.iter().sum();
        let weights: Vec<f32> =
            examples.iter().map(|&n| n as f32 / total as f32).collect();
        let updates: Vec<ParamVector> =
            vectors.into_iter().map(ParamVector::from_vec).collect();
        let batch = ParamVector::weighted_sum(&updates, &weights);

        for (a, b) in streamed.as_slice().iter().zip(batch.as_slice()) {
            assert_close(*a as f64, *b as f64, 1e-6)?;
        }
        Ok(())
    });
}

#[test]
fn prop_reorder_buffer_restores_selection_order_from_any_arrival() {
    // Whatever completion order the pool produces, folds happen in
    // selection order — the heart of the bit-identity guarantee.
    check(60, |rng| {
        let n = rng.range_i64(1, 30) as usize;
        // Random arrival permutation.
        let mut arrival: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut arrival);
        let mut buf = ReorderBuffer::new(n);
        let mut released = Vec::new();
        for &i in &arrival {
            buf.accept(FitOutcomeSlim {
                index: i,
                client_id: i as u32,
                result: Ok(FitResult {
                    client: i as u32,
                    params: ParamVector::zeros(1),
                    num_examples: 1,
                    mean_loss: 0.0,
                    emu: FitReport::synthetic(1, 1, 0.0),
                    comm_s: 0.0,
                }),
            });
            while let Some(out) = buf.pop_ready() {
                released.push(out.index);
            }
        }
        assert_that(buf.held_back() == 0, || "outcomes left behind".to_string())?;
        assert_that(
            released == (0..n).collect::<Vec<_>>(),
            || format!("arrival {arrival:?} released {released:?}"),
        )
    });
}

#[test]
fn prop_availability_traces_deterministic_per_seed_and_query_order() {
    // Same seed + same model => the same timeline, no matter how (or in
    // what order) the trace is queried.  This is what makes a scenario
    // reproducible across runs and across `--workers N`.
    check(30, |rng| {
        let seed = rng.next_u64();
        let model = match rng.below(3) {
            0 => AvailabilityModel::Diurnal {
                period_s: rng.range_f64(50.0, 500.0),
                online_fraction: rng.range_f64(0.05, 0.95),
            },
            1 => AvailabilityModel::Battery {
                drain_s: rng.range_f64(10.0, 100.0),
                recharge_s: rng.range_f64(5.0, 50.0),
                jitter: rng.range_f64(0.0, 0.8),
            },
            _ => AvailabilityModel::ExponentialChurn {
                mean_online_s: rng.range_f64(10.0, 100.0),
                mean_offline_s: rng.range_f64(5.0, 50.0),
            },
        };
        let mut a = AvailabilityTrace::new(model.clone(), Pcg::new(seed, 3));
        let mut b = AvailabilityTrace::new(model, Pcg::new(seed, 3));
        let ts: Vec<f64> = (0..50).map(|_| rng.range_f64(0.0, 3000.0)).collect();
        // Warm b with a completely different (reversed, scaled) query
        // pattern before comparing.
        for &t in ts.iter().rev() {
            let _ = b.is_online(t * 1.7);
        }
        for &t in &ts {
            assert_that(a.is_online(t) == b.is_online(t), || {
                format!("is_online diverged at t={t}")
            })?;
            assert_that(
                a.next_offline_after(t).to_bits() == b.next_offline_after(t).to_bits(),
                || format!("next_offline_after diverged at t={t}"),
            )?;
            assert_that(
                a.next_online_after(t).to_bits() == b.next_online_after(t).to_bits(),
                || format!("next_online_after diverged at t={t}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_round_gate_matches_deadline_sequential() {
    // The streaming gate (1 slot, always-online traces) is the ported
    // DeadlineSequential: identical kept spans and drops.  Round length
    // matches the oracle for clean rounds; when stragglers were cut the
    // gate records the full deadline (the server held the round open that
    // long), which the oracle's completed-work timeline does not.
    check(60, |rng| {
        let n = rng.range_i64(1, 25) as usize;
        let durations: Vec<(u32, f64)> = (0..n)
            .map(|i| (i as u32, rng.range_f64(0.1, 6.0)))
            .collect();
        let deadline = rng.range_f64(0.5, 20.0);
        let oracle = DeadlineSequential::new(deadline).run(&durations);

        let mut gate = RoundGate::new(0.0, deadline, 1);
        let mut dropped = Vec::new();
        for &(c, d) in &durations {
            let mut on = AvailabilityTrace::from_toggles(true, vec![]);
            if let GateVerdict::Late { .. } = gate.admit(&mut on, c, d) {
                dropped.push(c);
            }
        }
        let sched = gate.schedule();
        assert_that(dropped == oracle.dropped, || {
            format!("drops diverged: gate {dropped:?} vs oracle {:?}", oracle.dropped)
        })?;
        assert_that(sched.spans == oracle.schedule.spans, || {
            "kept spans diverged from DeadlineSequential".to_string()
        })?;
        if dropped.is_empty() {
            assert_close(sched.round_s, oracle.schedule.round_s, 1e-12)
        } else {
            assert_that(sched.round_s.to_bits() == deadline.to_bits(), || {
                format!(
                    "late round must last the deadline: {} vs {deadline}",
                    sched.round_s
                )
            })
        }
    });
}

#[test]
fn prop_dropped_clients_never_reach_the_accumulator() {
    // Whatever mix of dropouts (offline boundary) and deadline misses a
    // round produces, the streaming mean must equal the weighted mean of
    // exactly the kept clients — dropped updates leave no residue.
    check(40, |rng| {
        let n = rng.range_i64(2, 20) as usize;
        let p = rng.range_i64(1, 100) as usize;
        let deadline = if rng.f64() < 0.5 { rng.range_f64(1.0, 15.0) } else { f64::INFINITY };
        let mut gate = RoundGate::new(0.0, deadline, 1);
        let mut acc = StreamingMean::new(p);
        let mut kept_vecs: Vec<(Vec<f32>, usize)> = Vec::new();
        let mut kept_count = 0usize;
        for c in 0..n {
            let dur = rng.range_f64(0.2, 4.0);
            // Half the clients get an offline boundary somewhere nearby.
            let mut trace = if rng.f64() < 0.5 {
                AvailabilityTrace::from_toggles(true, vec![rng.range_f64(0.1, 12.0)])
            } else {
                AvailabilityTrace::from_toggles(true, vec![])
            };
            let vals: Vec<f32> = (0..p).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let examples = rng.range_i64(1, 300) as usize;
            let result = FitResult {
                client: c as u32,
                params: ParamVector::from_vec(vals.clone()),
                num_examples: examples,
                mean_loss: 1.0,
                emu: FitReport::synthetic(1, 1, dur),
                comm_s: 0.0,
            };
            match gate.admit(&mut trace, c as u32, dur) {
                GateVerdict::Keep { .. } => {
                    acc.push(result).map_err(|e| e.to_string())?;
                    kept_vecs.push((vals, examples));
                    kept_count += 1;
                }
                GateVerdict::Dropout { .. } | GateVerdict::Late { .. } => {
                    // result dropped on the floor, exactly like the server.
                }
            }
            assert_that(acc.len() == kept_count, || {
                format!("accumulator saw {} clients, kept {kept_count}", acc.len())
            })?;
        }
        if kept_count == 0 {
            return Ok(()); // empty round: nothing to compare
        }
        let streamed = match Box::new(acc).finish().map_err(|e| e.to_string())? {
            AccOutput::Mean(m) => m.params,
            AccOutput::Buffered(_) => return Err("expected Mean output".into()),
        };
        let total: usize = kept_vecs.iter().map(|(_, e)| e).sum();
        let weights: Vec<f32> =
            kept_vecs.iter().map(|(_, e)| *e as f32 / total as f32).collect();
        let updates: Vec<ParamVector> = kept_vecs
            .into_iter()
            .map(|(v, _)| ParamVector::from_vec(v))
            .collect();
        let batch = ParamVector::weighted_sum(&updates, &weights);
        for (a, b) in streamed.as_slice().iter().zip(batch.as_slice()) {
            assert_close(*a as f64, *b as f64, 1e-6)?;
        }
        Ok(())
    });
}

#[test]
fn prop_selection_stream_matches_the_materialized_engine_below_threshold() {
    // The population refactor's RNG-compatibility contract: below the
    // documented threshold (`fl::population::DENSE_POPULATION_MAX`),
    // `ClientManager::select` draws exactly the stream the historical
    // engine drew — `select_from` over a freshly-built identity pool.
    check(40, |rng| {
        let n = rng.range_i64(1, 200) as usize;
        let seed = rng.next_u64();
        let selection = match rng.below(3) {
            0 => Selection::All,
            1 => Selection::Fraction(rng.range_f64(0.05, 1.0)),
            _ => Selection::Count(rng.range_i64(1, 2 * n as i64) as usize),
        };
        let mut mgr = ClientManager::new(seed, selection);
        let mut oracle = ClientManager::new(seed, selection);
        for round in 0..4 {
            let everyone: Vec<usize> = (0..n).collect();
            let want = oracle.select_from(&everyone);
            let got = mgr.select(n).to_vec();
            assert_that(got == want, || {
                format!("round {round}, n={n}, {selection:?}: {got:?} vs {want:?}")
            })?;
        }
        Ok(())
    });
}

#[test]
fn population_engine_is_bit_identical_to_the_materialized_engine() {
    // Tentpole acceptance: a small federation materialized as live
    // clients and the same federation run through the Population/factory
    // path produce bit-identical History, schedule and aggregates —
    // across workers {1, 4} and every scenario preset.
    for &preset in SCENARIO_PRESETS {
        for workers in [1usize, 4] {
            let build = |population: bool| {
                let mut b = Experiment::builder()
                    .clients(10)
                    .rounds(6)
                    .samples_per_client(40)
                    .batch(16)
                    .selection(Selection::Fraction(0.6))
                    .network(true)
                    .seed(13)
                    .workers(workers)
                    .scenario_named(preset)
                    .eval_every(0)
                    .fail_on_empty_round(false)
                    .simulated(96);
                if population {
                    b = b.population(10);
                }
                b.build().expect("experiment builds")
            };
            let label = format!("{preset}/workers={workers}");
            let a = build(false).run().expect("materialized run");
            let b = build(true).run().expect("population run");
            assert_eq!(a.global.len(), b.global.len(), "{label}");
            for (x, y) in a.global.as_slice().iter().zip(b.global.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{label}: aggregate diverged");
            }
            assert_eq!(a.history.rounds.len(), b.history.rounds.len(), "{label}");
            for (r1, r2) in a.history.rounds.iter().zip(&b.history.rounds) {
                assert_eq!(r1.selected, r2.selected, "{label}: round {}", r1.round);
                assert_eq!(
                    r1.train_loss.to_bits(),
                    r2.train_loss.to_bits(),
                    "{label}: round {}",
                    r1.round
                );
                assert_eq!(
                    r1.emu_round_s.to_bits(),
                    r2.emu_round_s.to_bits(),
                    "{label}: round {}",
                    r1.round
                );
                assert_eq!(
                    r1.failures.len(),
                    r2.failures.len(),
                    "{label}: round {}",
                    r1.round
                );
                for (f1, f2) in r1.failures.iter().zip(&r2.failures) {
                    assert_eq!(f1.client, f2.client, "{label}");
                    assert_eq!(f1.reason, f2.reason, "{label}");
                }
            }
            assert_eq!(a.trace.events, b.trace.events, "{label}: schedule diverged");
        }
    }
}

#[test]
fn virtual_population_runs_in_cohort_memory() {
    // Above the dense threshold the run must touch only O(cohort) state:
    // a 50k-client high-churn federation with Count(16) completes every
    // round, selects at most the cohort, and reports the deduplicated
    // profile table instead of 50k per-client profiles.
    let report = Experiment::builder()
        .population(50_000)
        .rounds(5)
        .selection(Selection::Count(16))
        .scenario_named("high-churn")
        .batch(16)
        .eval_every(0)
        .fail_on_empty_round(false)
        .seed(3)
        .simulated(64)
        .build()
        .expect("virtual population builds")
        .run()
        .expect("virtual population runs");
    assert_eq!(report.history.rounds.len(), 5);
    assert!(report.history.rounds.iter().any(|r| !r.selected.is_empty()));
    for r in &report.history.rounds {
        assert!(r.selected.len() <= 16, "cohort overflow: {}", r.selected.len());
        assert!(r.selected.iter().all(|&c| (c as usize) < 50_000));
    }
    assert!(
        report.profiles.len() <= 256,
        "virtual population materialized {} profiles",
        report.profiles.len()
    );
}

#[test]
fn prop_weighted_sum_linearity() {
    check(40, |rng| {
        let n = rng.range_i64(1, 200) as usize;
        let k = rng.range_i64(1, 8) as usize;
        let vs: Vec<ParamVector> = (0..k)
            .map(|_| {
                ParamVector::from_vec((0..n).map(|_| rng.normal() as f32).collect())
            })
            .collect();
        let w: Vec<f32> = (0..k).map(|_| rng.f32()).collect();
        let a = ParamVector::weighted_sum(&vs, &w);
        // Scaling all weights by c scales the output by c.
        let w2: Vec<f32> = w.iter().map(|x| 2.0 * x).collect();
        let b = ParamVector::weighted_sum(&vs, &w2);
        for i in 0..n {
            assert_close(
                b.as_slice()[i] as f64,
                2.0 * a.as_slice()[i] as f64,
                1e-4,
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_correlations_bounded_and_consistent() {
    check(60, |rng| {
        let n = rng.range_i64(3, 40) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let ys: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        for r in [pearson(&xs, &ys), spearman(&xs, &ys), kendall_tau_b(&xs, &ys)] {
            assert_that((-1.0 - 1e-9..=1.0 + 1e-9).contains(&r), || format!("{r} out of [-1,1]"))?;
        }
        // Perfect agreement with itself.
        assert_close(spearman(&xs, &xs), 1.0, 1e-12)?;
        assert_close(kendall_tau_b(&xs, &xs), 1.0, 1e-12)
    });
}

#[test]
fn prop_trimmed_mean_bounded_by_extremes() {
    check(40, |rng| {
        let n = rng.range_i64(1, 50) as usize;
        let k = rng.range_i64(3, 9) as usize;
        let vs: Vec<ParamVector> = (0..k)
            .map(|_| {
                ParamVector::from_vec((0..n).map(|_| rng.normal() as f32).collect())
            })
            .collect();
        let trim = rng.below((k - 1) / 2 + 1).min((k - 1) / 2);
        let out = ParamVector::trimmed_mean(&vs, trim);
        for i in 0..n {
            let col: Vec<f32> = vs.iter().map(|v| v.as_slice()[i]).collect();
            let lo = col.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = col.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let x = out.as_slice()[i];
            assert_that(x >= lo - 1e-6 && x <= hi + 1e-6, || {
                format!("coordinate {i}: {x} outside [{lo}, {hi}]")
            })?;
        }
        Ok(())
    });
}

// --- detlint satellite: bit-identity of the streams whose state moved
// --- from HashMap to BTreeMap (DESIGN.md §15, R1) -------------------

/// Lazy dynamics must answer identically whatever order clients were
/// first touched in: the eligibility stream and the wakeup scan are
/// functions of (seed, client, round), not of the cache's insertion
/// history.  This is the property the `DynState::Lazy` BTreeMaps make
/// structural — an unordered map would satisfy it only as long as no
/// code path ever iterated the cache.
#[test]
fn prop_lazy_dynamics_query_order_independent() {
    check(20, |rng| {
        let seed = rng.next_u64();
        let clients = rng.range_i64(20, 120) as usize;
        let model = AvailabilityModel::ExponentialChurn {
            mean_online_s: rng.range_f64(5.0, 60.0),
            mean_offline_s: rng.range_f64(5.0, 60.0),
        };
        let join = rng.range_f64(0.0, 0.2);
        let leave = rng.range_f64(0.0, 0.2);
        let mk = || FederationDynamics::new_lazy(seed, clients, &model, join, leave, 30.0, 4);
        let (mut fwd, mut rev) = (mk(), mk());
        for round in 0..4 {
            fwd.begin_round();
            rev.begin_round();
            let now = fwd.now_s();
            // Touch `fwd` ascending and `rev` descending, so the two
            // caches are populated in opposite orders.
            let ef: Vec<bool> = (0..clients).map(|c| fwd.is_eligible(c, now)).collect();
            let mut er = vec![false; clients];
            for c in (0..clients).rev() {
                er[c] = rev.is_eligible(c, now);
            }
            assert_that(ef == er, || {
                format!("round {round}: eligibility depends on query order (seed {seed})")
            })?;
            // The full sweep and the wakeup scan see the same caches.
            assert_that(fwd.eligible_at(now) == rev.eligible_at(now), || {
                format!("round {round}: eligible_at depends on query order")
            })?;
            let (wf, wr) = (fwd.next_wakeup_after(now), rev.next_wakeup_after(now));
            assert_that(wf == wr, || {
                format!("round {round}: wakeup {wf:?} vs {wr:?} (seed {seed})")
            })?;
            let dt = rng.range_f64(1.0, 30.0);
            fwd.advance(dt);
            rev.advance(dt);
        }
        Ok(())
    });
}

/// Identically-seeded samplers must stream the identical deduplicated
/// profile table: same entries in the same order, bitwise-equal weights
/// and CDF.  The table's name index is a BTreeMap so this holds by
/// construction; selection at population scale draws against this CDF,
/// so any wobble here would fan out into every selection stream.
#[test]
fn prop_profile_table_streams_bit_identical() {
    check(10, |rng| {
        let seed = rng.next_u64();
        let draws = rng.range_i64(50, 400) as usize;
        let table = |s| {
            HardwareSampler::with_defaults(s)
                .sample_table(draws, |_| true)
                .expect("unfiltered sampling cannot exhaust the budget")
        };
        let (a, b) = (table(seed), table(seed));
        assert_that(a.len() == b.len(), || {
            format!("table sizes differ: {} vs {}", a.len(), b.len())
        })?;
        assert_that(a.profiles() == b.profiles(), || {
            "profile streams diverged between identically-seeded samplers".to_string()
        })?;
        // Bitwise, not approximate: weights and CDF feed selection.
        assert_that(a.weights() == b.weights(), || "weights diverged".to_string())?;
        assert_that(a.cdf() == b.cdf(), || "cdf diverged".to_string())
    });
}

// --- fold-plan satellite: the tree reduction's contracts ------------
// --- (`--fold-plan tree`, DESIGN.md §16) ----------------------------

/// The tree fold must land within 1e-6 of the serial streaming mean on
/// random cohorts — the tolerance `--fold-plan tree` documents.  Exact
/// equality is NOT promised (the pairwise merges re-associate the f64
/// accumulation), which is why the plan is opt-in.
#[test]
fn prop_tree_fold_matches_serial_within_tolerance() {
    check(40, |rng| {
        let p = rng.range_i64(1, 400) as usize;
        let k = rng.range_i64(1, 40) as usize;
        let mut serial = StreamingMean::new(p);
        let mut tree = TreeMean::new(p, k);
        for c in 0..k {
            let vals: Vec<f32> = (0..p).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let examples = rng.range_i64(1, 400) as usize;
            let result = |params| FitResult {
                client: c as u32,
                params,
                num_examples: examples,
                mean_loss: 0.0,
                emu: FitReport::synthetic(1, 1, 0.0),
                comm_s: 0.0,
            };
            serial
                .push(result(ParamVector::from_vec(vals.clone())))
                .map_err(|e| e.to_string())?;
            tree.push(result(ParamVector::from_vec(vals))).map_err(|e| e.to_string())?;
        }
        let finish = |acc: Box<dyn AggAccumulator>| match acc.finish() {
            Ok(AccOutput::Mean(m)) => Ok(m.params),
            Ok(AccOutput::Buffered(_)) => Err("expected Mean output".to_string()),
            Err(e) => Err(e.to_string()),
        };
        let s = finish(Box::new(serial))?;
        let t = finish(Box::new(tree))?;
        for (a, b) in s.as_slice().iter().zip(t.as_slice()) {
            assert_close(*a as f64, *b as f64, 1e-6)?;
        }
        Ok(())
    });
}

/// One federation under the tree plan; `axis` switches on the feature
/// that constrains where the folds may run (netsim/attack force the
/// folds back onto the server thread — worker-side folding is gated off).
fn tree_run(preset: &str, workers: usize, plan: &str, axis: &str, seed: u64) -> ExperimentReport {
    let mut b = Experiment::builder()
        .clients(8)
        .rounds(5)
        .samples_per_client(40)
        .batch(16)
        .selection(Selection::Fraction(0.75))
        .network(true)
        .seed(seed)
        .workers(workers)
        .fold_plan(plan)
        .scenario_named(preset)
        .eval_every(0)
        .fail_on_empty_round(false)
        .simulated(96);
    match axis {
        "netsim" => b = b.netsim_named("congested-cell"),
        "attack" => b = b.attack_named("sign-flip"),
        _ => {}
    }
    b.build()
        .unwrap_or_else(|e| panic!("{preset}/{axis}: build failed: {e}"))
        .run()
        .unwrap_or_else(|e| panic!("{preset}/{axis}: run failed: {e}"))
}

fn assert_bit_identical_runs(label: &str, a: &ExperimentReport, b: &ExperimentReport) {
    assert_eq!(a.global.len(), b.global.len(), "{label}: aggregate length");
    for (x, y) in a.global.as_slice().iter().zip(b.global.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: aggregate diverged");
    }
    assert_eq!(a.history.rounds.len(), b.history.rounds.len(), "{label}: round count");
    for (r1, r2) in a.history.rounds.iter().zip(&b.history.rounds) {
        assert_eq!(r1.selected, r2.selected, "{label}: round {}", r1.round);
        assert_eq!(
            r1.train_loss.to_bits(),
            r2.train_loss.to_bits(),
            "{label}: round {}",
            r1.round
        );
        assert_eq!(
            r1.emu_round_s.to_bits(),
            r2.emu_round_s.to_bits(),
            "{label}: round {}",
            r1.round
        );
        assert_eq!(r1.failures.len(), r2.failures.len(), "{label}: round {}", r1.round);
        for (f1, f2) in r1.failures.iter().zip(&r2.failures) {
            assert_eq!((f1.client, &f1.reason), (f2.client, &f2.reason), "{label}");
        }
    }
}

/// The tree plan's headline: the fold result is a function of the
/// selection, never of completion order — so the aggregate is
/// bit-identical across `--workers {1, 2, 4, 8}`, for every scenario
/// preset, and with the netsim/attack axes on (where the folds fall
/// back to the server thread).
#[test]
fn tree_fold_is_bit_identical_across_workers_scenarios_and_axes() {
    for &preset in SCENARIO_PRESETS {
        let reference = tree_run(preset, 1, "tree", "plain", 29);
        for workers in [2usize, 4, 8] {
            let w = tree_run(preset, workers, "tree", "plain", 29);
            assert_bit_identical_runs(&format!("{preset}/workers={workers}"), &reference, &w);
        }
    }
    for axis in ["netsim", "attack"] {
        let reference = tree_run("stable", 1, "tree", axis, 31);
        for workers in [2usize, 4, 8] {
            let w = tree_run("stable", workers, "tree", axis, 31);
            assert_bit_identical_runs(&format!("{axis}/workers={workers}"), &reference, &w);
        }
    }
}

/// Switching the fold plan changes aggregation arithmetic ONLY: the
/// selection stream, timeline and failure set are untouched, and the
/// global model tracks the serial plan within the documented 1e-6.
#[test]
fn tree_fold_tracks_the_serial_plan_within_tolerance() {
    let serial = tree_run("stable", 1, "serial", "plain", 47);
    let tree = tree_run("stable", 4, "tree", "plain", 47);
    assert_eq!(serial.history.rounds.len(), tree.history.rounds.len());
    for (r1, r2) in serial.history.rounds.iter().zip(&tree.history.rounds) {
        assert_eq!(r1.selected, r2.selected, "selection depends on the fold plan");
        assert_eq!(r1.failures.len(), r2.failures.len(), "failures depend on the fold plan");
    }
    assert_eq!(serial.global.len(), tree.global.len());
    for (a, b) in serial.global.as_slice().iter().zip(tree.global.as_slice()) {
        let (a, b) = (*a as f64, *b as f64);
        assert!(
            (a - b).abs() <= 1e-6 * a.abs().max(1.0),
            "fold plans diverged past tolerance: {a} vs {b}"
        );
    }
}

/// A tree-plan run crashed at a checkpoint boundary and resumed must be
/// bit-identical to the uninterrupted run — the fold topology is part of
/// the durable manifest, so the resumed half re-folds the same shape.
#[test]
fn tree_fold_resumed_from_checkpoint_is_bit_identical() {
    let dir = std::env::temp_dir()
        .join(format!("bouquetfl-fold-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mk = || {
        Experiment::builder()
            .clients(8)
            .rounds(6)
            .samples_per_client(40)
            .batch(16)
            .selection(Selection::Fraction(0.75))
            .network(true)
            .seed(53)
            .workers(4)
            .fold_plan("tree")
            .scenario_named("diurnal-mobile")
            .eval_every(0)
            .fail_on_empty_round(false)
            .simulated(96)
    };
    let crashed = mk()
        .durable_options(DurableOptions::new(&dir).crash_after(3))
        .build()
        .expect("crash-point run builds")
        .run();
    match crashed {
        Ok(_) => panic!("crash-point run unexpectedly succeeded"),
        Err(e) => {
            let msg = format!("{e}");
            assert!(msg.contains("crash point"), "unexpected error: {msg}");
        }
    }

    let resumed = mk()
        .resume(&dir)
        .build()
        .expect("resume builds")
        .run()
        .expect("resume runs");
    let unbroken = mk().build().expect("clean builds").run().expect("clean runs");
    assert_bit_identical_runs("tree fold resume", &resumed, &unbroken);
    let _ = std::fs::remove_dir_all(&dir);
}

/// One metrics-enabled federation; `axis` composes the comm and attack
/// layers on top of the scenario like `tree_run` above.  The returned
/// report's `sim_json().pretty()` string is the byte-identity surface
/// DESIGN.md §17 promises.
fn metrics_run(preset: &str, workers: usize, axis: &str, seed: u64) -> ExperimentReport {
    let mut b = Experiment::builder()
        .clients(8)
        .rounds(5)
        .samples_per_client(40)
        .batch(16)
        .selection(Selection::Fraction(0.75))
        .network(true)
        .seed(seed)
        .workers(workers)
        .scenario_named(preset)
        .eval_every(0)
        .fail_on_empty_round(false)
        .metrics()
        .simulated(96);
    match axis {
        "netsim" => b = b.netsim_named("congested-cell"),
        "attack" => b = b.attack_named("sign-flip"),
        _ => {}
    }
    b.build()
        .unwrap_or_else(|e| panic!("{preset}/{axis}: build failed: {e}"))
        .run()
        .unwrap_or_else(|e| panic!("{preset}/{axis}: run failed: {e}"))
}

/// The rendered simulated-domain metrics document from a report.
fn sim_doc(report: &ExperimentReport, label: &str) -> String {
    report
        .metrics
        .as_ref()
        .unwrap_or_else(|| panic!("{label}: .metrics() run carries no metrics"))
        .sim_json()
        .pretty()
}

/// Simulated-domain metrics are a pure fold over the event stream, and
/// events are emitted in selection order for any worker count — so the
/// whole metrics.json document is bit-identical across `--workers {1,4}`
/// for every scenario preset, with and without the netsim and attack
/// axes stacked on.  (Host-domain metrics are excluded by construction:
/// `sim_json` never touches them.)
#[test]
fn sim_metrics_bit_identical_across_workers_scenarios_and_axes() {
    for &preset in SCENARIO_PRESETS {
        for axis in ["plain", "netsim", "attack"] {
            let a = metrics_run(preset, 1, axis, 61);
            let b = metrics_run(preset, 4, axis, 61);
            let label = format!("{preset}/{axis}");
            let doc = sim_doc(&a, &label);
            assert_eq!(doc, sim_doc(&b, &label), "{label}: sim metrics diverged across workers");
            assert!(
                doc.contains("\"clients_selected\""),
                "{label}: the fold saw no selections:\n{doc}"
            );
            if axis == "netsim" {
                assert!(
                    doc.contains("\"comm_bytes_upload\""),
                    "{label}: netsim run recorded no comm bytes:\n{doc}"
                );
            }
            if axis == "attack" {
                assert!(
                    doc.contains("\"attack_injections\""),
                    "{label}: armed run recorded no injections:\n{doc}"
                );
            }
        }
    }
}

/// `bouquetfl stats` is the live observer run offline: folding a durable
/// run's event log through `durable::replay_metrics` must reproduce the
/// live run's metrics.json byte-for-byte — even when the live run is
/// itself a crash-and-resume stitched from a replayed prefix plus a
/// fresh tail, and the clean uninterrupted run must agree with both.
#[test]
fn stats_replay_matches_live_metrics_byte_for_byte() {
    let dir = std::env::temp_dir()
        .join(format!("bouquetfl-stats-replay-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mk = || {
        Experiment::builder()
            .clients(8)
            .rounds(6)
            .samples_per_client(40)
            .batch(16)
            .selection(Selection::Fraction(0.75))
            .network(true)
            .seed(67)
            .workers(4)
            .scenario_named("diurnal-mobile")
            .netsim_named("congested-cell")
            .eval_every(0)
            .fail_on_empty_round(false)
            .metrics()
            .simulated(96)
    };
    let crashed = mk()
        .durable_options(DurableOptions::new(&dir).crash_after(3))
        .build()
        .expect("crash-point run builds")
        .run();
    match crashed {
        Ok(_) => panic!("crash-point run unexpectedly succeeded"),
        Err(e) => {
            let msg = format!("{e}");
            assert!(msg.contains("crash point"), "unexpected error: {msg}");
        }
    }

    let resumed = mk().resume(&dir).build().expect("resume builds").run().expect("resume runs");
    let live = sim_doc(&resumed, "resumed");

    let log = durable::read_log(&dir.join(durable::EVENT_LOG_FILE)).expect("log reads");
    assert!(!log.truncated, "durable log has a torn tail");
    let stats = durable::replay_metrics(&log.events).sim_json().pretty();
    assert_eq!(stats, live, "stats fold diverged from the live observer");

    let unbroken = mk().build().expect("clean builds").run().expect("clean runs");
    assert_eq!(
        sim_doc(&unbroken, "unbroken"),
        live,
        "resumed metrics diverged from the uninterrupted run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
