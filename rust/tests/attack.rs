//! Attack-vs-defense property suite (DESIGN.md §13) — the adversarial
//! robustness lab's headline claims:
//!
//! (a) Krum and trimmed-mean converge under sign-flip / scaled attacks at
//!     attacker fractions where plain FedAvg measurably diverges.
//! (b) An armed attack with `fraction = 0` is bit-identical to the
//!     unattacked engine, across every scenario preset × workers {1, 4}.
//! (c) Attacked runs keep the determinism contract: bit-identical across
//!     worker counts and across the materialized-vs-population engines,
//!     including composed with netsim.
//!
//! The divergence tests drive a hand-assembled `ServerApp` with a custom
//! client that takes a real optimisation step each round (the builder's
//! `SimClient` echoes the global back, so a relative perturbation like
//! sign-flip would be inert there); the bit-identity tests go through the
//! full `Experiment` builder stack.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use bouquetfl::emu::{FitReport, VirtualClock};
use bouquetfl::error::EmuError;
use bouquetfl::fl::{
    Attack, AttackConfig, BouquetContext, ClientApp, ClientId, Experiment, FedAvg, FitConfig,
    FitResult, FlEvent, FlObserver, Krum, ParamVector, Selection, ServerApp, ServerConfig,
    Strategy, TrimmedMean, SCENARIO_PRESETS,
};
use bouquetfl::hardware::{preset, HardwareProfile};
use bouquetfl::sched::Sequential;

const DIM: usize = 32;
/// The honest fleet's shared optimum: every coordinate of the ideal model.
const W_STAR: f32 = 1.0;

/// A client that actually learns: each fit moves halfway from the current
/// global toward `W_STAR` on every coordinate.  Unattacked federations
/// therefore converge geometrically, which gives the divergence tests a
/// real signal for relative perturbations to flip.
struct DriftClient {
    id: ClientId,
    profile: HardwareProfile,
}

impl ClientApp for DriftClient {
    fn id(&self) -> ClientId {
        self.id
    }
    fn profile(&self) -> &HardwareProfile {
        &self.profile
    }
    fn num_examples(&self) -> usize {
        32
    }
    fn fit(
        &mut self,
        global: &ParamVector,
        _cfg: &FitConfig,
        _ctx: &mut BouquetContext<'_>,
    ) -> Result<FitResult, EmuError> {
        let mut params = global.clone();
        for x in params.as_mut_slice() {
            *x += 0.5 * (W_STAR - *x);
        }
        Ok(FitResult {
            client: self.id,
            params,
            num_examples: 32,
            mean_loss: 1.0,
            emu: FitReport::synthetic(1, 32, 0.25),
            comm_s: 0.0,
        })
    }
}

/// Count `AttackInjected` events from the engine's typed stream.
struct InjectionCounter(Arc<AtomicUsize>);

impl FlObserver for InjectionCounter {
    fn on_event(&mut self, event: &FlEvent<'_>) {
        if let FlEvent::AttackInjected { .. } = event {
            self.0.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Attacker membership is pure in `(seed, client)`, so the tests can pick
/// a seed that compromises exactly `want` of the first `n` clients —
/// deterministic, and independent of which defense runs on top.
fn seed_with_attackers(cfg: &AttackConfig, n: u64, want: usize) -> u64 {
    (0..10_000u64)
        .find(|&s| {
            let a = Attack::resolve(cfg, s).expect("valid attack config");
            (0..n).filter(|&i| a.is_attacker(i)).count() == want
        })
        .expect("some seed compromises exactly `want` clients")
}

/// Run `rounds` of a 10-client static federation from an all-zeros global
/// under `strategy`, optionally attacked; returns the final global and the
/// number of `AttackInjected` events observed.
fn run_defended(
    strategy: Box<dyn Strategy>,
    attack: Option<&AttackConfig>,
    seed: u64,
    rounds: u32,
) -> (ParamVector, usize) {
    let clients: Vec<Box<dyn ClientApp>> = (0..10)
        .map(|i| {
            Box::new(DriftClient {
                id: i as ClientId,
                profile: preset("budget-2019").expect("preset exists"),
            }) as Box<dyn ClientApp>
        })
        .collect();
    let cfg = ServerConfig {
        rounds,
        selection: Selection::All,
        fit: FitConfig::default(),
        eval_every: 0,
        seed,
        fail_on_empty_round: true,
    };
    let injections = Arc::new(AtomicUsize::new(0));
    let mut server = ServerApp::new(
        cfg,
        HardwareProfile::paper_host(),
        strategy,
        Box::new(Sequential),
        clients,
    )
    .with_observer(Box::new(InjectionCounter(Arc::clone(&injections))));
    if let Some(a) = attack {
        server = server.with_attack(Attack::resolve(a, seed).expect("valid attack config"));
    }
    let mut clock = VirtualClock::fast_forward();
    let (global, history) = server
        .run_from(ParamVector::zeros(DIM), None, &mut clock)
        .expect("federation runs");
    assert_eq!(history.rounds.len(), rounds as usize);
    (global, injections.load(Ordering::Relaxed))
}

/// Euclidean distance of `v` from the constant-`t` vector.
fn dist_from(v: &ParamVector, t: f32) -> f64 {
    v.as_slice()
        .iter()
        .map(|&x| ((x - t) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
}

#[test]
fn defenses_converge_under_sign_flip_where_fedavg_diverges() {
    // (a), sign-flip: 2 of 10 clients flip (and rescale x10) their update
    // around the round-start global.  The honest fixed point is W_STAR;
    // FedAvg's mean picks up a net repulsive term and blows up
    // geometrically, while Krum and trimmed-mean discard the flipped
    // updates and keep the honest contraction.
    let cfg = AttackConfig { model: "sign-flip".into(), fraction: 0.2, scale: 10.0 };
    let seed = seed_with_attackers(&cfg, 10, 2);
    let rounds = 8;

    let (honest, honest_inj) = run_defended(Box::new(FedAvg), None, seed, rounds);
    assert_eq!(honest_inj, 0);
    let baseline = dist_from(&honest, W_STAR);
    assert!(baseline < 0.1, "unattacked FedAvg must converge: {baseline}");

    let (avg, avg_inj) = run_defended(Box::new(FedAvg), Some(&cfg), seed, rounds);
    // Every round injects exactly the 2 compromised clients, and the event
    // stream reports each injection.
    assert_eq!(avg_inj, 2 * rounds as usize);
    let diverged = dist_from(&avg, W_STAR);
    assert!(
        diverged > (DIM as f64).sqrt(),
        "attacked FedAvg must end farther from the optimum than it started: {diverged}"
    );

    let (krum, krum_inj) = run_defended(Box::new(Krum::new(2, 1)), Some(&cfg), seed, rounds);
    assert_eq!(krum_inj, 2 * rounds as usize);
    let defended = dist_from(&krum, W_STAR);
    assert!(defended < 0.1, "Krum must converge under sign-flip: {defended}");

    let (tm, _) = run_defended(Box::new(TrimmedMean::new(2)), Some(&cfg), seed, rounds);
    let trimmed = dist_from(&tm, W_STAR);
    assert!(trimmed < 0.1, "trimmed-mean must converge under sign-flip: {trimmed}");
}

#[test]
fn defenses_converge_under_model_replacement_where_fedavg_is_hijacked() {
    // (a), scaled / model replacement: the same 2 compromised clients
    // submit `global + 10 * (target - global)` for a run-scoped random
    // target.  The boost overshoots the mean every round (|1 - 10 * 0.2| >
    // 1 around the induced fixed point), so FedAvg never settles at
    // W_STAR; the robust strategies never fold the replacement in.
    let cfg = AttackConfig::preset("scaled").expect("preset exists");
    assert_eq!(cfg.fraction, 0.2);
    let seed = seed_with_attackers(&cfg, 10, 2);
    let rounds = 8;

    let (avg, _) = run_defended(Box::new(FedAvg), Some(&cfg), seed, rounds);
    let hijacked = dist_from(&avg, W_STAR);
    assert!(
        hijacked > 1.0,
        "scaled attack must pull FedAvg off the optimum: {hijacked}"
    );

    let (krum, _) = run_defended(Box::new(Krum::new(2, 1)), Some(&cfg), seed, rounds);
    let defended = dist_from(&krum, W_STAR);
    assert!(defended < 0.1, "Krum must converge under replacement: {defended}");

    let (tm, _) = run_defended(Box::new(TrimmedMean::new(2)), Some(&cfg), seed, rounds);
    let trimmed = dist_from(&tm, W_STAR);
    assert!(trimmed < 0.1, "trimmed-mean must converge under replacement: {trimmed}");
}

/// Assert two experiment reports are bit-identical in everything the
/// determinism contract covers: final global, per-round history, and the
/// emulated schedule trace.
fn assert_bit_identical(
    a: &bouquetfl::fl::ExperimentReport,
    b: &bouquetfl::fl::ExperimentReport,
    label: &str,
) {
    assert_eq!(a.global.len(), b.global.len(), "{label}");
    for (x, y) in a.global.as_slice().iter().zip(b.global.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: aggregate diverged");
    }
    assert_eq!(a.history.rounds.len(), b.history.rounds.len(), "{label}");
    for (r1, r2) in a.history.rounds.iter().zip(&b.history.rounds) {
        assert_eq!(r1.selected, r2.selected, "{label}: round {}", r1.round);
        assert_eq!(
            r1.train_loss.to_bits(),
            r2.train_loss.to_bits(),
            "{label}: round {}",
            r1.round
        );
        assert_eq!(
            r1.emu_round_s.to_bits(),
            r2.emu_round_s.to_bits(),
            "{label}: round {}",
            r1.round
        );
        assert_eq!(r1.failures.len(), r2.failures.len(), "{label}: round {}", r1.round);
        for (f1, f2) in r1.failures.iter().zip(&r2.failures) {
            assert_eq!(f1.client, f2.client, "{label}");
            assert_eq!(f1.reason, f2.reason, "{label}");
        }
    }
    assert_eq!(a.trace.events, b.trace.events, "{label}: schedule diverged");
}

#[test]
fn fraction_zero_is_bit_identical_to_the_unattacked_engine() {
    // (b): arming the attack machinery with fraction 0 must leave every
    // scenario preset bit-identical to a build without `.attack()`, at
    // workers 1 and 4.
    for &preset in SCENARIO_PRESETS {
        for workers in [1usize, 4] {
            let build = |armed: bool| {
                let mut b = Experiment::builder()
                    .clients(10)
                    .rounds(6)
                    .samples_per_client(40)
                    .batch(16)
                    .selection(Selection::Fraction(0.6))
                    .network(true)
                    .seed(13)
                    .workers(workers)
                    .scenario_named(preset)
                    .eval_every(0)
                    .fail_on_empty_round(false)
                    .simulated(96);
                if armed {
                    b = b.attack(AttackConfig {
                        model: "sign-flip".into(),
                        fraction: 0.0,
                        scale: 1.0,
                    });
                }
                b.build().expect("experiment builds")
            };
            let label = format!("{preset}/workers={workers}");
            let off = build(false).run().expect("unattacked run");
            let armed = build(true).run().expect("fraction-zero run");
            assert_bit_identical(&off, &armed, &label);
        }
    }
}

#[test]
fn attacked_runs_are_bit_identical_across_workers_and_engines() {
    // (c): an attacked run is a deterministic function of the experiment
    // seed — the same bits fall out of the sequential engine, the 4-worker
    // pool, and the below-threshold population engine, with and without
    // netsim composed on top.
    for (model, scale, netsim) in
        [("gauss", 1.5, false), ("scaled", 10.0, false), ("gauss", 1.5, true)]
    {
        let cfg = AttackConfig { model: model.into(), fraction: 0.5, scale };
        let build = |workers: usize, population: bool| {
            let mut b = Experiment::builder()
                .clients(10)
                .rounds(5)
                .samples_per_client(40)
                .batch(16)
                .selection(Selection::Fraction(0.6))
                .network(true)
                .seed(21)
                .workers(workers)
                .scenario_named("high-churn")
                .eval_every(0)
                .fail_on_empty_round(false)
                .attack(cfg.clone())
                .simulated(96);
            if population {
                b = b.population(10);
            }
            if netsim {
                b = b.netsim_named("congested-cell");
            }
            b.build().expect("experiment builds")
        };
        let baseline = build(1, false).run().expect("sequential materialized run");
        for (workers, population) in [(4, false), (1, true), (4, true)] {
            let label =
                format!("{model}/netsim={netsim}/workers={workers}/population={population}");
            let other = build(workers, population).run().expect("attacked run");
            assert_bit_identical(&baseline, &other, &label);
        }
    }
}

#[test]
fn an_armed_attack_changes_the_aggregate_and_reports_injections() {
    // Sanity for everything above: with a seed that provably compromises
    // clients, the builder-stack attack actually perturbs the aggregate,
    // and every fold of a compromised update surfaces as AttackInjected.
    let cfg = AttackConfig { model: "gauss".into(), fraction: 0.5, scale: 2.0 };
    let seed = seed_with_attackers(&cfg, 10, 5);
    let rounds = 4u32;
    let injections = Arc::new(AtomicUsize::new(0));
    let build = |armed: bool| {
        let mut b = Experiment::builder()
            .clients(10)
            .rounds(rounds)
            .samples_per_client(40)
            .batch(16)
            .selection(Selection::All)
            .seed(seed)
            .eval_every(0)
            .simulated(64);
        if armed {
            b = b
                .attack(cfg.clone())
                .observer(Box::new(InjectionCounter(Arc::clone(&injections))));
        }
        b.build().expect("experiment builds")
    };
    let off = build(false).run().expect("unattacked run");
    let on = build(true).run().expect("attacked run");
    assert!(
        off.global
            .as_slice()
            .iter()
            .zip(on.global.as_slice())
            .any(|(x, y)| x.to_bits() != y.to_bits()),
        "an armed gauss attack must change the aggregate"
    );
    // Selection::All folds all 5 compromised clients every round.
    assert_eq!(injections.load(Ordering::Relaxed), 5 * rounds as usize);
}
