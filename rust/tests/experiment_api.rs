//! The library-first experiment API (DESIGN.md §10):
//!
//! * `Experiment::builder()` must be **bit-identical** to the historical
//!   `launch()` path for every scenario preset × `--workers {1,4}`.
//! * Registries must round-trip every built-in component and accept
//!   downstream registrations.
//! * The `with_scenario`-before-`with_scheduler` ordering footgun must be
//!   gone: dynamics compile against the *final* scheduler at run time.
//! * A campaign sweep must run end-to-end from one API call and emit one
//!   JSONL row per cell, with coordinate-derived deterministic seeds.
//! * The typed event stream must arrive complete and in order.

use std::sync::{Arc, Mutex};

use bouquetfl::emu::VirtualClock;
use bouquetfl::error::FlError;
use bouquetfl::fl::launcher::{launch, HardwareSource, LaunchOptions};
use bouquetfl::fl::strategy::{self, StrategyFactory};
use bouquetfl::fl::{
    Campaign, ClientApp, Experiment, FedAvg, FitResult, FlEvent, FlObserver, History,
    ParamVector, Scenario, ServerApp, ServerConfig, SimClient, Strategy, SCENARIO_PRESETS,
};
use bouquetfl::hardware::HardwareProfile;
use bouquetfl::modelcost::resnet18_cifar;
use bouquetfl::runtime::ModelExecutor;
use bouquetfl::sched::dynamics::AvailabilityModel;
use bouquetfl::sched::{self, LimitedParallel, Scheduler, Sequential, Trace};
use bouquetfl::util::json::Json;

/// Serialises every test that spawns restricted environments: with
/// `Isolation::Strict` (workers = 1, sequential scheduler) the env
/// counter is process-global, and cargo runs test fns on many threads.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn env_guard() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

const PROFILES: [&str; 3] = ["gtx-1060", "rtx-3060", "gtx-1650"];

/// Real-execution tests need the AOT artifact set; mirror the rest of the
/// suite's environment instead of failing where `fl_pipeline.rs` would
/// fail too.
fn runtime_available() -> bool {
    ModelExecutor::new(&bouquetfl::runtime::default_dir()).is_ok()
}

fn tiny_opts() -> LaunchOptions {
    LaunchOptions {
        clients: 3,
        rounds: 2,
        samples_per_client: 48,
        eval_samples: 128,
        batch: 16,
        local_steps: 2,
        lr: 0.02,
        eval_every: 2,
        seed: 7,
        hardware: HardwareSource::Manual(PROFILES.iter().map(|s| s.to_string()).collect()),
        ..Default::default()
    }
}

fn assert_identical(
    label: &str,
    (ga, ha, ta): (&ParamVector, &History, &Trace),
    (gb, hb, tb): (&ParamVector, &History, &Trace),
) {
    assert_eq!(ga.len(), gb.len(), "{label}: param dim");
    for (a, b) in ga.as_slice().iter().zip(gb.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: aggregate drifted");
    }
    assert_eq!(ha.rounds.len(), hb.rounds.len(), "{label}: round count");
    for (r1, r2) in ha.rounds.iter().zip(&hb.rounds) {
        assert_eq!(r1.selected, r2.selected, "{label}: round {}", r1.round);
        assert_eq!(
            r1.train_loss.to_bits(),
            r2.train_loss.to_bits(),
            "{label}: round {}",
            r1.round
        );
        assert_eq!(
            r1.emu_round_s.to_bits(),
            r2.emu_round_s.to_bits(),
            "{label}: round {}",
            r1.round
        );
        assert_eq!(
            r1.eval_loss.map(f32::to_bits),
            r2.eval_loss.map(f32::to_bits),
            "{label}: round {}",
            r1.round
        );
        assert_eq!(
            r1.eval_accuracy.map(f32::to_bits),
            r2.eval_accuracy.map(f32::to_bits),
            "{label}: round {}",
            r1.round
        );
        assert_eq!(r1.failures.len(), r2.failures.len(), "{label}: round {}", r1.round);
        for (f1, f2) in r1.failures.iter().zip(&r2.failures) {
            assert_eq!(f1.client, f2.client, "{label}");
            assert_eq!(f1.reason, f2.reason, "{label}");
        }
    }
    assert_eq!(ta.events, tb.events, "{label}: trace spans drifted");
}

// ---------------------------------------------------------------------
// Tentpole acceptance: builder vs launch(), every preset × workers {1,4}.
// ---------------------------------------------------------------------

#[test]
fn builder_is_bit_identical_to_launch_for_every_preset_and_worker_count() {
    let _guard = env_guard();
    if !runtime_available() {
        eprintln!("skipping: no AOT artifacts in this environment");
        return;
    }
    for &preset in SCENARIO_PRESETS {
        for workers in [1usize, 4] {
            let label = format!("{preset}/workers={workers}");
            let sc = Scenario::preset(preset).unwrap();

            let mut opts = tiny_opts();
            opts.workers = workers;
            opts.scenario = (!sc.is_static()).then(|| sc.clone());
            let old = launch(&opts).unwrap_or_else(|e| panic!("{label}: launch: {e}"));

            // Builder path, deliberately in a scrambled setter order (the
            // scenario lands before workers/strategy — the old footgun).
            let new = Experiment::builder()
                .scenario(sc)
                .workers(workers)
                .samples_per_client(48)
                .eval_samples(128)
                .batch(16)
                .local_steps(2)
                .lr(0.02)
                .eval_every(2)
                .seed(7)
                .clients(3)
                .profiles(&PROFILES)
                .strategy("fedavg")
                .rounds(2)
                .build()
                .unwrap_or_else(|e| panic!("{label}: build: {e}"))
                .run()
                .unwrap_or_else(|e| panic!("{label}: run: {e}"));

            assert_identical(
                &label,
                (&old.global, &old.history, &old.trace),
                (&new.global, &new.history, &new.trace),
            );
        }
    }
}

// ---------------------------------------------------------------------
// Registries.
// ---------------------------------------------------------------------

struct NullStrategy;

impl Strategy for NullStrategy {
    fn name(&self) -> &'static str {
        "null"
    }

    fn aggregate(
        &mut self,
        global: &ParamVector,
        _results: &[FitResult],
        _executor: Option<&mut ModelExecutor>,
    ) -> Result<ParamVector, FlError> {
        Ok(global.clone())
    }
}

#[test]
fn registries_round_trip_every_builtin_component() {
    for name in strategy::names() {
        let s = strategy::by_name(&name)
            .unwrap_or_else(|| panic!("registered strategy '{name}' must resolve"));
        assert_eq!(s.name(), name, "strategy registry key must match Strategy::name");
    }
    assert!(strategy::names().len() >= 6, "all six built-ins registered");
    assert!(strategy::by_name("does-not-exist").is_none());

    for name in sched::names() {
        let s = sched::by_name(&name, 3)
            .unwrap_or_else(|| panic!("registered scheduler '{name}' must resolve"));
        assert_eq!(s.name(), name, "scheduler registry key must match Scheduler::name");
    }
    assert_eq!(sched::by_name("limited-parallel", 4).unwrap().max_concurrency(), 4);
    assert_eq!(sched::for_parallelism(1).name(), "sequential");
    assert_eq!(sched::for_parallelism(4).max_concurrency(), 4);
}

#[test]
fn downstream_strategy_registration_reaches_every_resolution_path() {
    strategy::register(
        "null",
        Arc::new(|| Box::new(NullStrategy) as Box<dyn Strategy>) as StrategyFactory,
    );
    assert!(strategy::names().contains(&"null".to_string()));
    assert_eq!(strategy::by_name("null").unwrap().name(), "null");
    // The builder resolves it like any built-in.
    let exp = Experiment::builder()
        .profiles(&["gtx-1060"])
        .clients(2)
        .strategy("null")
        .build()
        .unwrap();
    assert_eq!(exp.options().strategy, "null");
    // And the legacy options path shares the same registry.
    let opts = LaunchOptions { strategy: "null".into(), ..Default::default() };
    assert_eq!(opts.strategy_box().unwrap().name(), "null");
}

// ---------------------------------------------------------------------
// Ordering footgun: scenario slots must come from the FINAL scheduler.
// ---------------------------------------------------------------------

fn sim_fleet(n: u32) -> Vec<Box<dyn ClientApp>> {
    (0..n)
        .map(|i| {
            Box::new(SimClient::new(i, HardwareProfile::paper_host(), 64, resnet18_cifar()))
                as Box<dyn ClientApp>
        })
        .collect()
}

fn sim_server(n: u32, rounds: u32) -> ServerApp {
    let mut cfg = ServerConfig {
        rounds,
        eval_every: 0,
        seed: 11,
        ..Default::default()
    };
    cfg.fit.batch = 16;
    ServerApp::new(
        cfg,
        HardwareProfile::paper_host(),
        Box::new(FedAvg),
        Box::new(Sequential),
        sim_fleet(n),
    )
}

fn run_sim(mut server: ServerApp) -> (ParamVector, History, Trace) {
    let mut clock = VirtualClock::fast_forward();
    let (global, history) =
        server.run_from(ParamVector::zeros(8), None, &mut clock).expect("sim run");
    let trace = std::mem::take(&mut server.trace);
    (global, history, trace)
}

#[test]
fn with_scenario_before_with_scheduler_uses_the_final_slot_count() {
    let _guard = env_guard();
    // Measure one client's emulated fit duration d (identical hardware
    // across the fleet => identical durations).
    let (_, probe, _) = run_sim(sim_server(1, 1));
    let d = probe.rounds[0].emu_round_s;
    assert!(d > 0.0);

    // Deadline between d and 2d: packed onto 3 slots, clients 0-2 finish
    // at d (kept) and 3-5 at 2d (late).  Packed onto 1 slot — what the old
    // eager compile would have used for the scenario-first order — only
    // client 0 would survive.
    let sc = Scenario {
        name: "probe-deadline".into(),
        availability: AvailabilityModel::AlwaysOn,
        join_prob: 0.0,
        leave_prob: 0.0,
        round_deadline_s: 1.5 * d,
    };

    // The previously-wrong order: scenario attached while the default
    // sequential scheduler was still in place.
    let scenario_first = sim_server(6, 2)
        .with_scenario(&sc)
        .with_scheduler(Box::new(LimitedParallel::new(3)));
    // The canonical order.
    let scheduler_first = sim_server(6, 2)
        .with_scheduler(Box::new(LimitedParallel::new(3)))
        .with_scenario(&sc);

    let a = run_sim(scenario_first);
    let b = run_sim(scheduler_first);
    assert_identical("footgun", (&a.0, &a.1, &a.2), (&b.0, &b.1, &b.2));

    // And both reflect 3 emulated slots: exactly clients 3-5 are late.
    for r in &a.1.rounds {
        assert_eq!(r.selected.len(), 6, "round {}", r.round);
        let late: Vec<u32> = r.failures.iter().map(|f| f.client).collect();
        assert_eq!(late, vec![3, 4, 5], "round {}: slot count was wrong", r.round);
        assert!(
            r.failures.iter().all(|f| f.reason.starts_with("deadline:")),
            "round {}: {:?}",
            r.round,
            r.failures
        );
    }
}

// ---------------------------------------------------------------------
// Simulated experiments: worker invariance through the builder.
// ---------------------------------------------------------------------

#[test]
fn simulated_experiments_are_worker_count_invariant() {
    let _guard = env_guard();
    let run = |workers: usize| {
        Experiment::builder()
            .profiles(&["gtx-1060", "rtx-3060"])
            .clients(6)
            .rounds(3)
            .batch(16)
            .samples_per_client(32)
            .eval_every(0)
            .seed(9)
            .scenario(Scenario::preset("high-churn").unwrap())
            .workers(workers)
            .simulated(48)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let a = run(1);
    let b = run(4);
    assert_identical(
        "sim-workers",
        (&a.global, &a.history, &a.trace),
        (&b.global, &b.history, &b.trace),
    );
    assert_eq!(a.scenario, "high-churn");
    assert_eq!(a.strategy, "fedavg");
}

// ---------------------------------------------------------------------
// Campaigns: one call, per-cell JSONL, deterministic cell seeds.
// ---------------------------------------------------------------------

#[test]
fn campaign_runs_end_to_end_and_emits_one_jsonl_row_per_cell() {
    let _guard = env_guard();
    let base = LaunchOptions {
        clients: 4,
        rounds: 2,
        samples_per_client: 32,
        batch: 16,
        eval_every: 0,
        hardware: HardwareSource::Manual(vec!["gtx-1060".into(), "rtx-3060".into()]),
        ..Default::default()
    };
    let campaign = Campaign::new("smoke", base)
        .seeds(&[1, 2])
        .strategies(&["fedavg", "fedprox"])
        .scenarios(&[
            Scenario::preset("stable").unwrap(),
            Scenario::preset("high-churn").unwrap(),
        ])
        .simulated(64);

    let report = campaign.run();
    assert_eq!(report.cells.len(), 8);
    assert_eq!(report.succeeded(), 8, "{}", report.to_jsonl());

    let jsonl = report.to_jsonl();
    let rows: Vec<Json> = jsonl
        .lines()
        .map(|line| Json::parse(line).expect("every row is valid JSON"))
        .collect();
    assert_eq!(rows.len(), 8);
    for row in &rows {
        assert_eq!(row.get("rounds").unwrap().as_u64(), Some(2));
        assert!(row.get("strategy").unwrap().as_str().is_some());
        assert!(row.get("scenario").unwrap().as_str().is_some());
        assert!(row
            .get("cell_seed")
            .unwrap()
            .as_str()
            .unwrap()
            .parse::<u64>()
            .is_ok());
        assert!(row.get("total_emu_s").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(row.get("error"), Some(&Json::Null));
    }

    // Deterministic: the same campaign reruns to the same bytes.
    assert_eq!(report.to_jsonl(), campaign.run().to_jsonl());

    // File export round-trips.
    let path = std::env::temp_dir().join("bouquet_campaign_smoke.jsonl");
    report.write_jsonl(&path).unwrap();
    assert_eq!(std::fs::read_to_string(&path).unwrap(), jsonl);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn campaign_real_mode_sweeps_strategies_with_real_training() {
    let _guard = env_guard();
    if !runtime_available() {
        eprintln!("skipping: no AOT artifacts in this environment");
        return;
    }
    let base = LaunchOptions {
        rounds: 1,
        eval_every: 1,
        ..tiny_opts()
    };
    let report = Campaign::new("real-smoke", base)
        .strategies(&["fedavg", "fedprox"])
        .run();
    assert_eq!(report.cells.len(), 2);
    assert_eq!(report.succeeded(), 2, "{}", report.to_jsonl());
    for cell in &report.cells {
        assert!(cell.final_train_loss.unwrap().is_finite());
        assert!(cell.eval_loss.is_some(), "eval ran on the real executor");
        assert_eq!(cell.cell.scenario, "stable");
    }
    // Same coordinates, different strategies => different derived seeds.
    assert_ne!(report.cells[0].cell.cell_seed, report.cells[1].cell.cell_seed);
}

// ---------------------------------------------------------------------
// Event stream: complete and ordered.
// ---------------------------------------------------------------------

#[derive(Default)]
struct Collector {
    tags: Arc<Mutex<Vec<String>>>,
}

impl FlObserver for Collector {
    fn on_event(&mut self, event: &FlEvent<'_>) {
        let tag = match event {
            FlEvent::RunBegin { .. } => "run_begin".to_string(),
            FlEvent::RoundBegin { round, selected } => {
                format!("round_begin:{round}:{}", selected.len())
            }
            FlEvent::RoundSkipped { round, .. } => format!("round_skipped:{round}"),
            FlEvent::CommStarted { client, direction, .. } => {
                format!("comm_started:{client}:{direction:?}")
            }
            FlEvent::CommFinished { client, direction, .. } => {
                format!("comm_finished:{client}:{direction:?}")
            }
            FlEvent::ClientDone { client, .. } => format!("client_done:{client}"),
            FlEvent::ClientFailed { client, kind, .. } => {
                format!("client_failed:{client}:{kind:?}")
            }
            FlEvent::RoundScheduled { round, .. } => format!("scheduled:{round}"),
            FlEvent::Aggregated { round, survivors } => {
                format!("aggregated:{round}:{survivors}")
            }
            FlEvent::Evaluated { round, .. } => format!("evaluated:{round}"),
            FlEvent::RoundEnd { record } => format!("round_end:{}", record.round),
            FlEvent::RunEnd { .. } => "run_end".to_string(),
        };
        self.tags.lock().unwrap().push(tag);
    }
}

#[test]
fn event_stream_is_complete_and_in_selection_order() {
    let _guard = env_guard();
    let tags = Arc::new(Mutex::new(Vec::new()));
    let report = Experiment::builder()
        .profiles(&["gtx-1060", "rtx-3060"])
        .clients(3)
        .rounds(2)
        .batch(16)
        .samples_per_client(32)
        .eval_every(0)
        .seed(5)
        .observer(Box::new(Collector { tags: Arc::clone(&tags) }))
        .simulated(32)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(report.history.rounds.len(), 2);

    let got = tags.lock().unwrap().clone();
    let expected: Vec<String> = [
        "run_begin",
        "round_begin:0:3",
        "client_done:0",
        "client_done:1",
        "client_done:2",
        "scheduled:0",
        "aggregated:0:3",
        "round_end:0",
        "round_begin:1:3",
        "client_done:0",
        "client_done:1",
        "client_done:2",
        "scheduled:1",
        "aggregated:1:3",
        "round_end:1",
        "run_end",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(got, expected);
}
