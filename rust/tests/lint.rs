//! detlint fixture suite + the tier-1 self-lint (DESIGN.md §15).
//!
//! One fixture per rule proves it fires at the expected line; one per
//! rule proves `// detlint: allow(..)` silences it; the hygiene
//! fixtures prove unused and malformed allows are themselves findings.
//! Finally `self_lint_tree_is_clean` runs the linter in-process over
//! this crate's own `src/`, so a determinism hazard anywhere in the
//! tree fails tier-1 — not just the CI job.

use bouquetfl::lint::{lint_source, lint_tree, report::Report};

/// Active (rule, line) pairs from linting `src` under `path`.
fn active(path: &str, src: &str) -> Vec<(String, u32)> {
    lint_source(path, src)
        .active()
        .map(|f| (f.rule.clone(), f.line))
        .collect()
}

fn assert_clean(rep: &Report) {
    assert!(rep.is_clean(), "expected clean, got:\n{}", rep.render_text());
}

// ---------------------------------------------------------------- R1

#[test]
fn r1_fires_on_hashmap_state_at_expected_line() {
    let src = "use std::collections::HashMap;\n\
               pub struct Lazy {\n\
               \x20   traces: HashMap<usize, f64>,\n\
               }\n\
               fn sweep(m: &HashMap<u32, u32>) -> u32 {\n\
               \x20   m.values().sum()\n\
               }\n";
    assert_eq!(
        active("sched/dynamics.rs", src),
        vec![("R1".to_string(), 3), ("R1".to_string(), 5)],
        "import on line 1 must be exempt; type positions must fire"
    );
}

#[test]
fn r1_suppression_silences_and_is_consumed() {
    let src = "pub struct Lazy {\n\
               \x20   // detlint: allow(R1) — never iterated: per-key lookups only\n\
               \x20   traces: HashMap<usize, f64>,\n\
               }\n";
    let rep = lint_source("sched/dynamics.rs", src);
    assert_clean(&rep);
    assert_eq!(rep.suppressed_count(), 1);
    assert_eq!(rep.findings[0].reason, "never iterated: per-key lookups only");
}

#[test]
fn r1_ignores_test_modules() {
    let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn t() { let m: HashMap<u32, u32> = HashMap::new(); let _ = m; }\n}\n";
    assert_eq!(active("sched/dynamics.rs", src), vec![]);
}

// ---------------------------------------------------------------- R2

#[test]
fn r2_fires_on_wall_clock_at_expected_line() {
    let src = "fn round() {\n    let t0 = Instant::now();\n    let _ = t0;\n}\n";
    assert_eq!(active("fl/server.rs", src), vec![("R2".to_string(), 2)]);
}

#[test]
fn r2_allowlists_the_timing_seams() {
    let src = "fn bench() { let t0 = Instant::now(); let _ = t0; }\n";
    assert_eq!(active("util/benchkit.rs", src), vec![]);
    assert_eq!(active("emu/clock.rs", src), vec![]);
}

#[test]
fn r2_suppression_silences() {
    let src = "fn round() {\n\
               \x20   // detlint: allow(R2) — diagnostic host timing only\n\
               \x20   let t0 = Instant::now();\n\
               \x20   let _ = t0;\n\
               }\n";
    assert_clean(&lint_source("fl/server.rs", src));
}

// ---------------------------------------------------------------- R3

#[test]
fn r3_fires_on_literal_seed_and_entropy_at_expected_lines() {
    let src = "fn f(seed: u64) {\n\
               \x20   let ok = Pcg::seeded(seed);\n\
               \x20   let bad = Pcg::seeded(7);\n\
               \x20   let s: RandomState = Default::default();\n\
               }\n";
    assert_eq!(
        active("fl/client.rs", src),
        vec![("R3".to_string(), 3), ("R3".to_string(), 4)],
        "seed-derived construction on line 2 must not fire"
    );
}

#[test]
fn r3_suppression_silences() {
    let src = "fn f() {\n\
               \x20   // detlint: allow(R3) — placeholder stream, never drawn from\n\
               \x20   let rng = Pcg::seeded(0);\n\
               \x20   let _ = rng;\n\
               }\n";
    assert_clean(&lint_source("fl/client.rs", src));
}

// ---------------------------------------------------------------- R4

#[test]
fn r4_fires_on_env_probe_at_expected_line() {
    let src = "fn f() -> usize {\n    let w = std::thread::available_parallelism();\n    w.map(|n| n.get()).unwrap_or(1)\n}\n";
    assert_eq!(active("sched/pool.rs", src), vec![("R4".to_string(), 2)]);
    let env = "fn g() { let v = std::env::var(\"X\"); let _ = v; }\n";
    assert_eq!(active("emu/env.rs", env), vec![("R4".to_string(), 1)]);
}

#[test]
fn r4_allowlists_the_launcher() {
    let src = "fn g() { let v = std::env::var(\"X\"); let _ = v; }\n";
    assert_eq!(active("fl/launcher.rs", src), vec![]);
    assert_eq!(active("main.rs", src), vec![]);
}

#[test]
fn r4_suppression_silences() {
    let src = "fn g() {\n\
               \x20   // detlint: allow(R4) — log level only shapes stderr\n\
               \x20   let v = std::env::var(\"BOUQUET_LOG\");\n\
               \x20   let _ = v;\n\
               }\n";
    assert_clean(&lint_source("util/logging.rs", src));
}

// ---------------------------------------------------------------- R5

#[test]
fn r5_fires_on_panic_paths_at_expected_lines() {
    let src = "fn decode(buf: &[u8]) -> u32 {\n\
               \x20   let head = &buf[0..4];\n\
               \x20   let x: [u8; 4] = head.try_into().unwrap();\n\
               \x20   u32::from_le_bytes(x)\n\
               }\n";
    assert_eq!(
        active("durable/eventlog.rs", src),
        vec![("R5".to_string(), 2), ("R5".to_string(), 3)]
    );
}

#[test]
fn r5_only_applies_to_durable() {
    let src = "fn f(v: &[u8]) -> u8 { v[0] }\n";
    assert_eq!(active("fl/server.rs", src), vec![]);
    assert_eq!(active("durable/checkpoint.rs", src), vec![("R5".to_string(), 1)]);
}

#[test]
fn r5_suppression_silences() {
    let src = "fn f(v: &[u8]) -> u8 {\n\
               \x20   // detlint: allow(R5) — length checked by caller above\n\
               \x20   v[0]\n\
               }\n";
    assert_clean(&lint_source("durable/replay.rs", src));
}

// -------------------------------------------------- suppression hygiene

#[test]
fn unused_allow_is_an_a0_finding() {
    let src = "// detlint: allow(R2) — there is no clock here\nfn f() {}\n";
    assert_eq!(active("fl/server.rs", src), vec![("A0".to_string(), 1)]);
}

#[test]
fn allow_without_reason_is_an_a1_finding() {
    let src = "// detlint: allow(R2)\nfn f() { let t = Instant::now(); let _ = t; }\n";
    let found = active("fl/server.rs", src);
    assert!(
        found.contains(&("A1".to_string(), 1)),
        "reasonless allow must be malformed, got {found:?}"
    );
    assert!(
        found.contains(&("R2".to_string(), 2)),
        "malformed allow must not suppress, got {found:?}"
    );
}

#[test]
fn doc_comments_describing_the_grammar_are_inert() {
    let src = "/// Suppress with `// detlint: allow(R1) — reason`.\nfn f() {}\n";
    assert_eq!(active("lint/mod.rs", src), vec![]);
}

// ------------------------------------------------------ the self-lint

/// Tier-1's own gate: the crate's source tree lints clean, in-process.
/// This is what makes "re-introduce a bare HashMap in sched/dynamics.rs"
/// fail `cargo test`, not just the CI lint job.
#[test]
fn self_lint_tree_is_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let rep = lint_tree(&root).expect("lint walk failed");
    assert!(rep.files_scanned > 50, "walk saw only {} files", rep.files_scanned);
    assert!(
        rep.is_clean(),
        "determinism hazards in the tree:\n{}",
        rep.render_text()
    );
    // Every suppression in the tree must carry a written justification.
    for f in &rep.findings {
        if f.suppressed {
            assert!(
                !f.reason.trim().is_empty(),
                "{}:{} suppressed without a reason",
                f.path,
                f.line
            );
        }
    }
    // The five sanctioned suppressions (server R2, dynamics R3, logging
    // and artifact R4, obs host-clock R2) — if this count drifts, a
    // hazard was waived (or fixed) without updating DESIGN.md §15's
    // suppression table.
    assert_eq!(
        rep.suppressed_count(),
        5,
        "suppression set changed:\n{}",
        rep.render_text()
    );
}

/// The parallel-reduction seam arrived suppression-free: the tree fold
/// (fl/strategy/fold.rs), the grouped fair-share loop and the benchdiff
/// gate each lint clean under R1-R4 with zero `detlint: allow` comments,
/// so the sanctioned-suppression count above stays at exactly four.
/// (They are also inside the `self_lint_tree_is_clean` walk; this pins
/// the per-file zero-allow claim explicitly.)
#[test]
fn fold_fairshare_and_benchdiff_lint_clean_without_suppressions() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    for rel in ["fl/strategy/fold.rs", "netsim/fairshare.rs", "bin/benchdiff.rs"] {
        let src = std::fs::read_to_string(root.join(rel))
            .unwrap_or_else(|e| panic!("{rel}: {e}"));
        let rep = lint_source(rel, &src);
        assert!(rep.is_clean(), "{rel} has hazards:\n{}", rep.render_text());
        assert_eq!(
            rep.suppressed_count(),
            0,
            "{rel} grew a suppression:\n{}",
            rep.render_text()
        );
    }
}

/// The observability layer keeps the host/simulated domain split honest
/// at the lint level: the single wall-clock read lives in `obs/host.rs`
/// behind exactly one audited R2 allow (DESIGN.md §17), and every other
/// obs file — the registry, the event fold, the span model, the
/// exporters — lints clean with zero suppressions.
#[test]
fn obs_wall_clock_is_confined_to_host_rs() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let host = std::fs::read_to_string(root.join("obs/host.rs"))
        .unwrap_or_else(|e| panic!("obs/host.rs: {e}"));
    let rep = lint_source("obs/host.rs", &host);
    assert!(rep.is_clean(), "obs/host.rs has hazards:\n{}", rep.render_text());
    assert_eq!(
        rep.suppressed_count(),
        1,
        "obs/host.rs must hold exactly the audited host-clock allow:\n{}",
        rep.render_text()
    );
    for rel in ["obs/mod.rs", "obs/registry.rs", "obs/span.rs", "obs/observer.rs", "obs/exporters.rs"] {
        let src = std::fs::read_to_string(root.join(rel))
            .unwrap_or_else(|e| panic!("{rel}: {e}"));
        let rep = lint_source(rel, &src);
        assert!(rep.is_clean(), "{rel} has hazards:\n{}", rep.render_text());
        assert_eq!(
            rep.suppressed_count(),
            0,
            "{rel} grew a suppression:\n{}",
            rep.render_text()
        );
    }
}

/// The JSON artifact CI uploads parses back and agrees with the report.
#[test]
fn report_json_matches_report() {
    let rep = lint_tree(&std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src"))
        .expect("lint walk failed");
    let json = bouquetfl::util::json::Json::parse(&rep.to_json().dump()).expect("valid json");
    assert_eq!(json.get("clean").and_then(|j| j.as_bool()), Some(rep.is_clean()));
    assert_eq!(
        json.get("suppressed").and_then(|j| j.as_u64()),
        Some(rep.suppressed_count() as u64)
    );
    assert_eq!(
        json.get("findings").and_then(|j| j.as_arr()).map(|a| a.len()),
        Some(rep.findings.len())
    );
}
