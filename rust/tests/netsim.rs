//! Acceptance properties of the netsim subsystem (DESIGN.md §12):
//!
//! * netsim **disabled** leaves the engine bit-identical — across every
//!   scenario preset and worker count, and through the config-file path
//!   (`[netsim] enabled = false`).
//! * **unlimited capacity + identity codec** reproduces the closed-form
//!   `round_comm_s` timeline to 1e-9, per client, end to end.
//! * the fair-share timeline is **deterministic and independent of the
//!   worker count**, contention included.
//! * a population-scale (1M-client) federation runs with netsim enabled
//!   in O(cohort) state.
//! * the `RoundGate` deadline interaction with comm time: a client whose
//!   *upload* crosses the deadline is recorded `deadline:` and never
//!   folds into the accumulator.

use std::sync::{Arc, Mutex};

use bouquetfl::emu::VirtualClock;
use bouquetfl::fl::history::DEADLINE_REASON_PREFIX;
use bouquetfl::fl::{
    ClientApp, Experiment, ExperimentBuilder, ExperimentReport, FedAvg, FlEvent, FlObserver,
    LaunchOptions, ParamVector, Selection, ServerApp, ServerConfig, SimClient,
    SCENARIO_PRESETS,
};
use bouquetfl::hardware::{preset, HardwareProfile};
use bouquetfl::modelcost::resnet18_cifar;
use bouquetfl::net::NET_TIERS;
use bouquetfl::netsim::{simulate, NetSimConfig, Transfer};
use bouquetfl::sched::dynamics::{AvailabilityModel, FederationDynamics};
use bouquetfl::sched::Sequential;
use bouquetfl::util::cfg::Cfg;
use bouquetfl::util::prop::{assert_that, check};

const P: usize = 64;

// ---------------------------------------------------------------------
// Simulator-level properties.
// ---------------------------------------------------------------------

#[test]
fn prop_uncapped_timeline_matches_the_closed_form() {
    // With an uncapped pipe every flow runs at its own link rate: the
    // simulated finish equals arrival + latency + bytes*8/rate — the
    // closed-form `download_s`/`upload_s` — within 1e-9, regardless of
    // how many peers share the (infinite) pipe.
    check(60, |rng| {
        let n = rng.range_i64(1, 20) as usize;
        let transfers: Vec<Transfer> = (0..n)
            .map(|i| {
                let (tier, _) = *rng.choice(NET_TIERS);
                Transfer {
                    id: i as u32,
                    arrival_s: rng.range_f64(0.0, 50.0),
                    latency_s: tier.latency_ms / 1000.0,
                    bytes: rng.range_i64(1, 64 * 1024 * 1024) as u64,
                    link_mbps: tier.up_mbps,
                }
            })
            .collect();
        let done = simulate(&transfers, f64::INFINITY);
        for (t, c) in transfers.iter().zip(&done) {
            let expect =
                t.arrival_s + t.latency_s + t.bytes as f64 * 8.0 / (t.link_mbps * 1e6);
            assert_that((c.finish_s - expect).abs() < 1e-9, || {
                format!("flow {}: {} vs closed form {}", t.id, c.finish_s, expect)
            })?;
        }
        Ok(())
    });
}

#[test]
fn prop_fair_share_conserves_capacity_and_work() {
    // Finite pipe: no flow beats its contention-free time, and the whole
    // batch cannot finish faster than total-bits / capacity allows.
    check(40, |rng| {
        let n = rng.range_i64(2, 16) as usize;
        let capacity = rng.range_f64(5.0, 500.0);
        let transfers: Vec<Transfer> = (0..n)
            .map(|i| Transfer {
                id: i as u32,
                arrival_s: 0.0,
                latency_s: 0.0,
                bytes: rng.range_i64(1024, 8 * 1024 * 1024) as u64,
                link_mbps: rng.range_f64(1.0, 300.0),
            })
            .collect();
        let shared = simulate(&transfers, capacity);
        let alone = simulate(&transfers, f64::INFINITY);
        let total_bits: f64 = transfers.iter().map(|t| t.bytes as f64 * 8.0).sum();
        let makespan = shared.iter().map(|c| c.finish_s).fold(0.0, f64::max);
        assert_that(makespan >= total_bits / (capacity * 1e6) - 1e-9, || {
            format!("makespan {makespan} beats the capacity bound")
        })?;
        for (s, a) in shared.iter().zip(&alone) {
            assert_that(s.finish_s >= a.finish_s - 1e-9, || {
                format!("flow {} finished under contention before it could alone", s.id)
            })?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Engine-level properties.
// ---------------------------------------------------------------------

fn assert_reports_identical(a: &ExperimentReport, b: &ExperimentReport, label: &str) {
    assert_eq!(a.global.len(), b.global.len(), "{label}");
    for (x, y) in a.global.as_slice().iter().zip(b.global.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: aggregate diverged");
    }
    assert_eq!(a.history.rounds.len(), b.history.rounds.len(), "{label}");
    for (r1, r2) in a.history.rounds.iter().zip(&b.history.rounds) {
        assert_eq!(r1.selected, r2.selected, "{label}: round {}", r1.round);
        assert_eq!(
            r1.train_loss.to_bits(),
            r2.train_loss.to_bits(),
            "{label}: round {}",
            r1.round
        );
        assert_eq!(
            r1.emu_round_s.to_bits(),
            r2.emu_round_s.to_bits(),
            "{label}: round {}",
            r1.round
        );
        assert_eq!(r1.failures.len(), r2.failures.len(), "{label}: round {}", r1.round);
        for (f1, f2) in r1.failures.iter().zip(&r2.failures) {
            assert_eq!(f1.client, f2.client, "{label}");
            assert_eq!(f1.reason, f2.reason, "{label}");
        }
    }
    assert_eq!(a.trace.events, b.trace.events, "{label}: schedule diverged");
}

fn builder(preset_name: &str, workers: usize) -> ExperimentBuilder {
    Experiment::builder()
        .profiles(&["gtx-1060", "rtx-3060"])
        .clients(6)
        .rounds(5)
        .samples_per_client(40)
        .batch(16)
        .local_steps(2)
        .selection(Selection::Fraction(0.5))
        .network(true)
        .seed(11)
        .workers(workers)
        .scenario_named(preset_name)
        .eval_every(0)
        .fail_on_empty_round(false)
        .simulated(P)
}

#[test]
fn netsim_disabled_is_bit_identical_across_presets_workers_and_the_config_path() {
    // The acceptance contract for the *disabled* state: the engine with
    // the netsim code present (and a parsed-but-disabled `[netsim]`
    // section) produces exactly the pre-netsim output, for every scenario
    // preset x workers {1, 4}.
    for &preset_name in SCENARIO_PRESETS {
        for workers in [1usize, 4] {
            let label = format!("{preset_name}/workers={workers}");
            let via_builder = builder(preset_name, workers)
                .build()
                .expect("builds")
                .run()
                .expect("runs");
            let cfg = Cfg::parse(&format!(
                r#"
[federation]
clients = 6
rounds = 5
batch = 16
local_steps = 2
fraction = 0.5
network = true
seed = 11
workers = {workers}
eval_every = 0
fail_on_empty_round = false

[data]
samples_per_client = 40

[hardware]
profiles = ["gtx-1060", "rtx-3060"]

[scenario]
preset = "{preset_name}"

[netsim]
enabled = false
ingress_mbps = 50
"#
            ))
            .expect("config parses");
            let opts = LaunchOptions::from_cfg(&cfg).expect("options parse");
            assert!(opts.netsim.is_none(), "{label}: disabled netsim must resolve to None");
            let via_cfg = ExperimentBuilder::from_options(opts)
                .simulated(P)
                .build()
                .expect("builds from config")
                .run()
                .expect("runs from config");
            assert_reports_identical(&via_builder, &via_cfg, &label);
        }
    }
}

#[test]
fn uncapped_identity_netsim_reproduces_closed_form_windows_end_to_end() {
    // Same federation with and without netsim (uncapped pipes, identity
    // codec, payload pinned to the executed parameter vector): every kept
    // client's emulated window — trace span length — must agree to 1e-9
    // (fit + closed-form download + upload on both sides), and the
    // aggregates must be bit-identical (identity codec perturbs nothing,
    // folds happen in the same selection order).
    let base = || builder("stable", 1).selection(Selection::All);
    let off = base().build().expect("builds").run().expect("runs");
    let on = base()
        .netsim(NetSimConfig {
            payload_bytes: Some((P * 4) as u64),
            ..Default::default()
        })
        .build()
        .expect("builds")
        .run()
        .expect("runs");

    for (x, y) in off.global.as_slice().iter().zip(on.global.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits(), "identity codec must not perturb the aggregate");
    }
    assert_eq!(off.history.rounds.len(), on.history.rounds.len());
    for (a, b) in off.history.rounds.iter().zip(&on.history.rounds) {
        assert_eq!(a.selected, b.selected);
        assert!(a.failures.is_empty() && b.failures.is_empty(), "stable run must not drop");
        // Netsim models all clients concurrently: the round closes at the
        // slowest window (max), the sequential engine at the sum.
        assert!(b.emu_round_s <= a.emu_round_s + 1e-9);
        assert!(b.emu_round_s > 0.0);
    }

    // Per-client window equality via the traces: sequential spans have
    // length fit + round_comm_s; netsim spans run 0 -> upload end.
    let span_len = |report: &ExperimentReport, label: &str, client: u32| -> f64 {
        report
            .trace
            .events
            .iter()
            .find(|e| e.label == label && e.client == client)
            .map(|e| e.t_end_s - e.t_start_s)
            .unwrap_or(f64::NAN)
    };
    for (round, record) in off.history.rounds.iter().enumerate() {
        let label = format!("round{round}");
        for &client in &record.selected {
            let a = span_len(&off, &label, client);
            let b = span_len(&on, &label, client);
            assert!(
                (a - b).abs() < 1e-9,
                "round {round} client {client}: closed-form window {a} vs netsim {b}"
            );
        }
    }
}

#[test]
fn contended_netsim_is_bit_identical_across_worker_counts() {
    // Determinism + worker independence with real contention and a lossy
    // codec, under a dynamic scenario: the fair-share timeline is built
    // from selection-order data, so workers {1, 4} agree bit for bit.
    let run = |workers: usize| {
        builder("high-churn", workers)
            .netsim(NetSimConfig {
                ingress_mbps: 40.0,
                egress_mbps: 120.0,
                codec: "int8".into(),
                payload_bytes: Some(2 * 1024 * 1024),
                ..Default::default()
            })
            .build()
            .expect("builds")
            .run()
            .expect("runs")
    };
    let one = run(1);
    let four = run(4);
    assert_reports_identical(&one, &four, "contended netsim workers 1 vs 4");
    // Repeatability on top: a re-run is bit-identical too.
    assert_reports_identical(&one, &run(1), "contended netsim re-run");
}

#[test]
fn contention_slows_rounds_relative_to_uncapped() {
    let run = |cfg: NetSimConfig| {
        builder("stable", 1)
            .selection(Selection::All)
            .netsim(cfg)
            .build()
            .expect("builds")
            .run()
            .expect("runs")
    };
    let payload = Some((256 * 1024) as u64);
    let uncapped = run(NetSimConfig { payload_bytes: payload, ..Default::default() });
    let congested = run(NetSimConfig {
        ingress_mbps: 2.0,
        egress_mbps: 8.0,
        payload_bytes: payload,
        ..Default::default()
    });
    assert!(
        congested.total_emu_s() > uncapped.total_emu_s() + 1e-6,
        "shared-pipe contention must lengthen rounds: {} vs {}",
        congested.total_emu_s(),
        uncapped.total_emu_s()
    );
}

#[test]
fn million_client_population_with_netsim_stays_cohort_bounded() {
    // Acceptance: netsim composes with the population engine in O(cohort)
    // state — only the selected cohort's links/downloads/buffered fits
    // are ever materialised.
    let report = Experiment::builder()
        .population(1_000_000)
        .rounds(4)
        .selection(Selection::Count(32))
        .scenario_named("high-churn")
        .netsim(NetSimConfig {
            ingress_mbps: 300.0,
            egress_mbps: 1000.0,
            payload_bytes: Some(1024 * 1024),
            ..Default::default()
        })
        .batch(16)
        .eval_every(0)
        .fail_on_empty_round(false)
        .seed(5)
        .simulated(32)
        .build()
        .expect("million-client netsim experiment builds")
        .run()
        .expect("million-client netsim federation runs");
    assert_eq!(report.history.rounds.len(), 4);
    assert!(report.history.rounds.iter().any(|r| !r.selected.is_empty()));
    for r in &report.history.rounds {
        assert!(r.selected.len() <= 32, "cohort overflow: {}", r.selected.len());
    }
    assert!(
        report.profiles.len() <= 256,
        "netsim must not materialise per-client state ({} profiles)",
        report.profiles.len()
    );
}

// ---------------------------------------------------------------------
// Comm events.
// ---------------------------------------------------------------------

#[derive(Default)]
struct CommLog {
    // (round, client, is_download, started, at_s)
    events: Arc<Mutex<Vec<(u32, u32, bool, bool, f64)>>>,
    survivors: Arc<Mutex<Vec<usize>>>,
}

impl FlObserver for CommLog {
    fn on_event(&mut self, event: &FlEvent<'_>) {
        use bouquetfl::fl::CommDirection;
        match event {
            FlEvent::CommStarted { round, client, direction, at_s, .. } => {
                self.events.lock().unwrap().push((
                    *round,
                    *client,
                    *direction == CommDirection::Download,
                    true,
                    *at_s,
                ));
            }
            FlEvent::CommFinished { round, client, direction, at_s } => {
                self.events.lock().unwrap().push((
                    *round,
                    *client,
                    *direction == CommDirection::Download,
                    false,
                    *at_s,
                ));
            }
            FlEvent::Aggregated { survivors, .. } => {
                self.survivors.lock().unwrap().push(*survivors);
            }
            _ => {}
        }
    }
}

#[test]
fn comm_events_stream_in_selection_order_with_coherent_windows() {
    let log = CommLog::default();
    let events = Arc::clone(&log.events);
    let report = builder("stable", 1)
        .selection(Selection::All)
        .netsim(NetSimConfig {
            ingress_mbps: 25.0,
            payload_bytes: Some(512 * 1024),
            ..Default::default()
        })
        .observer(Box::new(log))
        .build()
        .expect("builds")
        .run()
        .expect("runs");

    let events = events.lock().unwrap();
    let rounds = report.history.rounds.len() as u32;
    for round in 0..rounds {
        let selected = &report.history.rounds[round as usize].selected;
        let n = selected.len();
        let per_round: Vec<_> =
            events.iter().filter(|e| e.0 == round).collect();
        // Phase-grouped: a download pair per *selected* client, then an
        // upload pair per successful fit (here: everyone), each phase in
        // selection order.
        assert_eq!(
            per_round.len(),
            n * 4,
            "round {round}: download pair per selected + upload pair per success"
        );
        for (k, &client) in selected.iter().enumerate() {
            let (d_start, d_end) = (per_round[2 * k], per_round[2 * k + 1]);
            let (u_start, u_end) =
                (per_round[2 * n + 2 * k], per_round[2 * n + 2 * k + 1]);
            assert!(
                [d_start, d_end, u_start, u_end].iter().all(|e| e.1 == client),
                "round {round}: selection order broke at client {client}"
            );
            // Download start at 0, download end <= upload start <= end.
            assert!(d_start.2 && d_start.3 && d_start.4 == 0.0);
            assert!(d_end.2 && !d_end.3);
            assert!(!u_start.2 && u_start.3);
            assert!(!u_end.2 && !u_end.3);
            assert!(d_end.4 <= u_start.4 && u_start.4 <= u_end.4);
        }
    }
}

// ---------------------------------------------------------------------
// RoundGate x comm-time satellite: an upload crossing the deadline.
// ---------------------------------------------------------------------

fn two_client_fleet(slow_tier_idx: usize) -> Vec<Box<dyn ClientApp>> {
    let profile = preset("gtx-1060").unwrap();
    let mut fast = SimClient::new(0, profile.clone(), 64, resnet18_cifar());
    fast.network = Some(NET_TIERS[0].0); // fiber: negligible comm
    let mut slow = SimClient::new(1, profile, 64, resnet18_cifar());
    slow.network = Some(NET_TIERS[slow_tier_idx].0);
    vec![Box::new(fast), Box::new(slow)]
}

fn run_two_clients(deadline_s: f64) -> (bouquetfl::fl::History, Vec<usize>) {
    let mut cfg = ServerConfig {
        rounds: 1,
        selection: Selection::All,
        eval_every: 0,
        seed: 3,
        fail_on_empty_round: false,
        ..Default::default()
    };
    cfg.fit.batch = 16;
    let log = CommLog::default();
    let survivors = Arc::clone(&log.survivors);
    let mut server = ServerApp::new(
        cfg,
        HardwareProfile::paper_host(),
        Box::new(FedAvg),
        Box::new(Sequential),
        two_client_fleet(4), // satellite: ~1.2s of latency-dominated comm
    )
    .with_observer(Box::new(log));
    if deadline_s.is_finite() {
        server = server.with_dynamics(FederationDynamics::new(
            3,
            2,
            &AvailabilityModel::AlwaysOn,
            0.0,
            0.0,
            deadline_s,
            1,
        ));
    }
    let (_, history) = server
        .run_from(ParamVector::zeros(P), None, &mut VirtualClock::fast_forward())
        .expect("two-client federation");
    let survivors = survivors.lock().unwrap().clone();
    (history, survivors)
}

#[test]
fn upload_crossing_the_deadline_is_late_and_never_folds() {
    // Phase 1 — open rounds: measure each client's full fit+comm window
    // from the sequential schedule, and split out the known closed-form
    // comm cost of the slow client's satellite link.
    let (open, survivors) = run_two_clients(f64::INFINITY);
    assert_eq!(survivors, vec![2], "open round keeps both clients");
    let round = &open.rounds[0];
    assert!(round.failures.is_empty());
    let dur_fast = round.emu_round_s; // sequential: sum of both windows
    // Recover the two windows from the emulated round: client 0 spans
    // [0, d0), client 1 [d0, d0+d1).  We need d0 and the slow client's
    // fit-only time; comm is closed-form (netsim is off here).
    let comm_slow = NET_TIERS[4].0.round_comm_s((P * 4) as u64);
    let comm_fast = NET_TIERS[0].0.round_comm_s((P * 4) as u64);
    // Both clients share hardware + workload, so their fit times are
    // equal; windows differ only by link. d0 = fit + comm_fast,
    // d1 = fit + comm_slow, round = d0 + d1.
    let fit = (dur_fast - comm_slow - comm_fast) / 2.0;
    assert!(fit > 0.0, "fit time must be positive (round {dur_fast})");
    let d0 = fit + comm_fast;
    let d1 = fit + comm_slow;

    // Phase 2 — a deadline the slow client's *fit* meets but its
    // *upload* misses: d0 + fit < deadline < d0 + d1.
    let deadline = d0 + fit + 0.5 * comm_slow;
    assert!(deadline < d0 + d1, "deadline must cut the upload window");
    let (gated, survivors) = run_two_clients(deadline);
    let round = &gated.rounds[0];
    assert_eq!(round.selected, vec![0, 1]);
    assert_eq!(round.failures.len(), 1, "only the slow upload misses");
    assert_eq!(round.failures[0].client, 1);
    assert!(
        round.failures[0].reason.starts_with(DEADLINE_REASON_PREFIX),
        "expected a deadline: reason, got '{}'",
        round.failures[0].reason
    );
    // The accumulator saw exactly one update — the late client's params
    // never folded.
    assert_eq!(survivors, vec![1], "late client must not reach the accumulator");
    // The deadline round is held open to the deadline itself.
    assert!((round.emu_round_s - deadline).abs() < 1e-9);
}
