//! Cross-module integration tests that do NOT need PJRT/artifacts:
//! sampler → restricted env → timing → figure harnesses.

use bouquetfl::analysis::claims;
use bouquetfl::analysis::fig2::{run as run_fig2, Fig2Config};
use bouquetfl::emu::{
    emulated_step_seconds, EmulationMode, Optimizer, VirtualClock,
};
use bouquetfl::emu::{EnvConfig, Isolation, RestrictedEnv};
use bouquetfl::fl::launcher::{feasible_on, sample_feasible};
use bouquetfl::hardware::gpu::FIG2_GPUS;
use bouquetfl::hardware::{HardwareProfile, HardwareSampler, SamplerConfig};
use bouquetfl::modelcost::{resnet18_cifar, small_cnn};
use bouquetfl::sched::{LimitedParallel, Scheduler, Sequential};

fn host() -> HardwareProfile {
    HardwareProfile::paper_host()
}

#[test]
fn sampled_federation_runs_restricted_fits_sequentially() {
    // Sample 10 feasible clients, run a timing-only fit for each, and
    // verify the sequential-isolation invariant through the trace.
    let mut sampler = HardwareSampler::with_defaults(3);
    let mut clock = VirtualClock::fast_forward();
    let w = small_cnn();
    let cfg = EnvConfig { isolation: Isolation::Concurrent, ..Default::default() };
    let mut durations = Vec::new();
    for i in 0..10u32 {
        let profile = sample_feasible(&mut sampler, &host()).unwrap();
        let mut env = RestrictedEnv::spawn(&profile, &host(), cfg.clone()).unwrap();
        let report = env
            .run_fit(&mut clock, &w, 32, 4, 50 * 1024 * 1024, |_| 0.5)
            .unwrap();
        env.teardown();
        durations.push((i, report.emu_total_s));
    }
    let schedule = Sequential.schedule(&durations);
    let trace = schedule.to_trace("integration");
    assert!(trace.is_serial(), "sequential schedule must never overlap");
    assert_eq!(trace.max_concurrency(), 1);
    let parallel = LimitedParallel::new(4).schedule(&durations);
    assert!(parallel.round_s <= schedule.round_s);
    assert!(parallel.to_trace("p").max_concurrency() <= 4);
}

#[test]
fn fig2_over_full_database_still_correlates() {
    // Beyond the paper's 13 GPUs: every host-feasible desktop GPU.
    let host = host();
    let slugs: Vec<&str> = bouquetfl::hardware::GPU_DB
        .iter()
        .filter(|g| !g.laptop)
        .filter(|g| {
            g.vram_gib <= host.gpu.vram_gib
                && g.peak_fp32_tflops() <= host.gpu.peak_fp32_tflops()
        })
        .map(|g| g.slug)
        .collect();
    assert!(slugs.len() >= 20, "{}", slugs.len());
    let cfg = Fig2Config { slugs, ..Default::default() };
    let r = run_fig2(&cfg).unwrap();
    assert!(r.spearman_rho > 0.8, "rho = {}", r.spearman_rho);
    assert!(r.kendall_tau > 0.6, "tau = {}", r.kendall_tau);
}

#[test]
fn host_restriction_approximates_device_model() {
    // The MPS-restriction emulation should track the direct device model
    // within ~35% for most of the paper's GPUs (bandwidth isolation is
    // partial by design — the paper's §3 approximation caveat).
    let w = resnet18_cifar();
    let mut rel_errors = Vec::new();
    for slug in FIG2_GPUS {
        let target = HardwareProfile::new(
            format!("t-{slug}"),
            bouquetfl::hardware::gpu_by_slug(slug).unwrap().clone(),
            host().cpu.clone(),
            host().ram,
        );
        let (a, _) = emulated_step_seconds(
            &target,
            &host(),
            EmulationMode::HostRestriction,
            &w,
            32,
            Optimizer::Sgd,
        )
        .unwrap();
        let (b, _) = emulated_step_seconds(
            &target,
            &host(),
            EmulationMode::DeviceModel,
            &w,
            32,
            Optimizer::Sgd,
        )
        .unwrap();
        rel_errors.push(((a - b) / b).abs());
    }
    let median = {
        let mut e = rel_errors.clone();
        e.sort_by(|a, b| a.total_cmp(b));
        e[e.len() / 2]
    };
    assert!(median < 0.5, "median relative error {median}; errors {rel_errors:?}");
}

#[test]
fn feasibility_filter_is_consistent() {
    let host = host();
    let mut sampler = HardwareSampler::new(5, SamplerConfig::default()).unwrap();
    for _ in 0..50 {
        let p = sample_feasible(&mut sampler, &host).unwrap();
        assert!(feasible_on(&p, &host));
    }
}

#[test]
fn all_claims_harnesses_produce_output() {
    let (oom_table, maxes) = claims::oom_matrix(claims::OOM_GPUS, claims::OOM_BATCHES);
    assert!(oom_table.num_rows() == claims::OOM_GPUS.len());
    assert!(maxes.iter().all(|(_, b)| *b >= 1));

    let (dl_table, rows) = claims::dataloader_sweep("rtx-4070-super", 32);
    assert!(dl_table.num_rows() >= 15);
    assert!(rows.iter().all(|(_, t, _)| *t > 0.0));

    let (ram_table, rows) = claims::ram_sweep(12.0);
    assert_eq!(ram_table.num_rows(), 7);
    assert!(rows.iter().any(|(_, p)| *p > 1.0));
}

#[test]
fn oom_cascade_from_sampler_federation() {
    // Draw a big federation and check that exactly the low-VRAM clients
    // fail at a large batch while the rest proceed — the paper's §4.2
    // failure-handling story at federation scale (timing-only).
    let w = resnet18_cifar();
    let mut sampler = HardwareSampler::with_defaults(11);
    let mut clock = VirtualClock::fast_forward();
    let cfg = EnvConfig { isolation: Isolation::Concurrent, ..Default::default() };
    let mut failed = 0;
    let mut survived = 0;
    for _ in 0..30 {
        let p = sample_feasible(&mut sampler, &host()).unwrap();
        let mut env = RestrictedEnv::spawn(&p, &host(), cfg.clone()).unwrap();
        match env.run_fit(&mut clock, &w, 256, 1, 0, |_| 0.0) {
            Ok(_) => survived += 1,
            Err(e) => {
                assert!(
                    matches!(e, bouquetfl::EmuError::GpuOom { .. }),
                    "only OOM failures expected: {e:?}"
                );
                failed += 1;
            }
        }
        env.teardown();
    }
    assert!(failed > 0, "batch 256 must OOM the 2-4 GiB cards");
    assert!(survived > 0, "batch 256 must fit the 8-12 GiB cards");
}
